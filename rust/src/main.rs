//! `fairsquare` CLI — the leader entrypoint.
//!
//! Subcommands:
//!
//! * `ratios`   — measured squares-per-mult ratios vs eq. (6)/(20)/(36)
//! * `gates`    — gate-level multiplier-vs-squarer report (E4/F9/F12)
//! * `simulate` — cycle-accurate runs of the Fig. 1–14 architectures
//! * `errors`   — floating-point error characterisation (E5)
//! * `serve`    — batching inference server over the AOT artifacts (E6)
//! * `list`     — artifacts available in the manifest

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use fairsquare::benchkit::{f, Table};
use fairsquare::cli::Args;
use fairsquare::coordinator::{
    InferenceServer, PjrtExecutor, QnnExecutor, QnnScalarExecutor, Routing, ServerStats,
    TileConfig, WorkloadGen,
};
use fairsquare::gates::report;
use fairsquare::ingress;
use fairsquare::linalg::counts::{eq20_ratio, eq36_ratio, eq6_ratio};
use fairsquare::linalg::{error, Matrix};
use fairsquare::sim;
use fairsquare::testkit::Rng;

const USAGE: &str = "\
fairsquare — square-based matmul/convolution reproduction

USAGE: fairsquare <command> [flags]

COMMANDS:
  ratios                         measured op-count ratios vs eq. 6/20/36
  gates     [--widths 4,8,..]    gate-level cost report (E4, F9, F12)
  simulate  [--size N]           cycle-accurate architecture runs
  errors                         float error of the square trick (E5)
  serve     [--artifacts DIR] [--model NAME] [--requests N] [--rps R]
            [--native] [--threads T] [--workers W] [--steal on|off]
            [--in-ch C] [--stride S] [--pad P] [--dilation D]
            [--tile-threshold COST] [--tile ROWS]
            [--heavy-frac N] [--heavy-size X]
                                 batching inference server demo (E6);
                                 --native serves the blocked square-kernel
                                 engine in-process (no PJRT artifacts)
                                 with --model one of
                                   dense    784→10 linear layer (default)
                                   conv     CNN filter bank (8 filters of
                                            C×3×3 over C×28×28 NCHW
                                            images) via the generalized
                                            im2col lowering, corrections
                                            cached once per bank;
                                            --in-ch C (default 1),
                                            --stride S (default 1),
                                            --pad P (default 0) and
                                            --dilation D (default 1) set
                                            the ConvSpec geometry, and
                                            every worker reuses a
                                            per-worker workspace arena
                                            (allocation free steady state
                                            with --threads 1; the
                                            threaded driver's spawns
                                            still allocate)
                                   complex  plane-split CPM3 complex
                                            matmul (64→16) fed QPSK
                                            symbols
                                   qnn      exact int8 two-layer MLP
                                            (784→64→10) served as int64
                                            rows end to end — requant
                                            (shift + saturating ReLU)
                                            fused into the blocked
                                            square engine, logits
                                            bit-exact vs the scalar
                                            QMlp::forward oracle
                                 each shadowed by its direct-multiplier
                                 twin (qnn: by the scalar integer
                                 oracle); without --native, --model names a
                                 PJRT artifact. --workers W shards the
                                 server into W worker threads behind one
                                 dispatcher that injects batches onto
                                 per-worker deques — every worker shares
                                 one prepared weight/bank/plane set, so
                                 the constant-operand (§3) corrections
                                 are computed exactly once for the whole
                                 pool. --steal on (default) lets an idle
                                 worker steal its siblings' oldest
                                 batches (shortest-queue injection);
                                 --steal off is the round-robin FIFO
                                 baseline for A/B runs. Native only: the
                                 PJRT engine is not Send, so the artifact
                                 path requires --workers 1 (the default).
                                 --threads T is the total engine thread
                                 budget, split across the workers.
                                 --tile-threshold COST (native only)
                                 turns on tile-granular intra-request
                                 parallelism: the dispatcher forks any
                                 batch whose estimated cost (light rows
                                 count 1, heavy rows --heavy-size)
                                 exceeds COST into --tile-row tile tasks
                                 (default 8 rows) spread across the
                                 whole pool — the §3.3 corrections are
                                 hoisted once per request, tiles write
                                 disjoint output slices, and the last
                                 tile to land joins the response.
                                 --heavy-frac N makes every N-th dense
                                 request heavy (the whale mix the e2e
                                 bench replays) and --heavy-size X
                                 prices a heavy request at X× a light
                                 one (default 32). All four knobs
                                 reject 0 instead of clamping.
            [--listen IP:PORT] [--models NAMES] [--clients K]
            [--cost-budget UNITS]
                                 network serving mode: bind a TCP
                                 ingress speaking the length-prefixed
                                 wire protocol (see README \"Network
                                 serving\"), register the --models set
                                 (default dense,conv,complex,qnn — each
                                 model's §3/§9 corrections hoisted once
                                 at registration, shared by all
                                 workers), then drive --requests
                                 round-robin across the models from
                                 --clients concurrent TCP connections
                                 (default 3) and print the pooled +
                                 per-model conservation-checked report.
                                 --listen rejects malformed addresses
                                 and port 0; --models rejects unknown
                                 and duplicate names. --cost-budget
                                 UNITS bounds each model's *queued*
                                 admission cost (dense rows cost 1,
                                 complex 2, qnn 3, conv 8); over-budget
                                 requests get a typed wire rejection
                                 (omit the flag for the count bound
                                 only; 0 is rejected, not clamped).
  list      [--artifacts DIR]    artifacts in the manifest
";

fn main() {
    let args = match Args::parse(
        &["artifacts", "model", "requests", "rps", "widths", "size", "seed", "threads",
          "workers", "steal", "in-ch", "stride", "pad", "dilation", "tile-threshold",
          "tile", "heavy-frac", "heavy-size", "listen", "models", "clients",
          "cost-budget"],
        &["verbose", "no-shadow", "native"],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_deref() {
        Some("ratios") => run(ratios(&args)),
        Some("gates") => run(gates(&args)),
        Some("simulate") => run(simulate(&args)),
        Some("errors") => run(errors(&args)),
        Some("serve") => run(serve(&args)),
        Some("list") => run(list(&args)),
        _ => {
            print!("{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn run(r: Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn ratios(_args: &Args) -> Result<()> {
    let mut rng = Rng::new(1);
    let sizes = [2usize, 4, 8, 16, 32, 64, 128];

    let mut t = Table::new(
        "E1 — real matmul, squares per multiplication (eq. 6)",
        &["M=N=P", "measured", "analytic", "limit"],
    );
    for &n in &sizes {
        let a = Matrix::random(&mut rng, n, n, -100, 100);
        let b = Matrix::random(&mut rng, n, n, -100, 100);
        let (_, d) = fairsquare::linalg::matmul::matmul_direct(&a, &b);
        let (_, s) = fairsquare::linalg::matmul::matmul_square(&a, &b);
        t.row(&[
            n.to_string(),
            f(s.square_ratio_vs(&d), 4),
            f(eq6_ratio(n as u64, n as u64), 4),
            "1.0".into(),
        ]);
    }
    t.print();

    let mut t = Table::new(
        "E2/E3 — complex matmul, squares per complex multiplication (eq. 20/36)",
        &["M=N=P", "CPM meas", "eq20", "CPM3 meas", "eq36"],
    );
    for &n in &sizes[..6] {
        let x = fairsquare::linalg::complex::CMatrix::from_fn(n, n, |_, _| {
            fairsquare::arith::Complex::new(rng.i64_in(-50, 50), rng.i64_in(-50, 50))
        });
        let y = fairsquare::linalg::complex::CMatrix::from_fn(n, n, |_, _| {
            fairsquare::arith::Complex::new(rng.i64_in(-50, 50), rng.i64_in(-50, 50))
        });
        let (_, d) = fairsquare::linalg::complex::cmatmul_direct(&x, &y);
        let (_, c4) = fairsquare::linalg::complex::cmatmul_cpm(&x, &y);
        let (_, c3) = fairsquare::linalg::complex::cmatmul_cpm3(&x, &y);
        let cmults = (d.mults / 4) as f64;
        t.row(&[
            n.to_string(),
            f(c4.squares as f64 / cmults, 4),
            f(eq20_ratio(n as u64, n as u64), 4),
            f(c3.squares as f64 / cmults, 4),
            f(eq36_ratio(n as u64, n as u64), 4),
        ]);
    }
    t.print();
    Ok(())
}

fn parse_widths(args: &Args) -> Result<Vec<usize>> {
    let spec = args.get_or("widths", "4,8,12,16,20,24");
    spec.split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad width {s:?}"))
        })
        .collect()
}

fn gates(args: &Args) -> Result<()> {
    let widths = parse_widths(args)?;
    let samples = if args.has("verbose") { 500 } else { 0 };

    let mut t = Table::new(
        "E4 — n×n multiplier vs n-bit squarer (verified netlists)",
        &["n", "mult gates", "mult area", "mult delay", "sq gates", "sq area",
          "sq delay", "area ratio"],
    );
    for r in report::core_comparison(&widths, samples) {
        t.row(&[
            r.n.to_string(),
            r.mult_gates.to_string(),
            f(r.mult_area, 1),
            f(r.mult_delay, 1),
            r.sq_gates.to_string(),
            f(r.sq_area, 1),
            f(r.sq_delay, 1),
            f(r.area_ratio, 3),
        ]);
    }
    t.print();

    let mut t = Table::new(
        "E4 ablation — reduction/architecture variants",
        &["variant", "n", "gates", "area", "delay"],
    );
    for r in report::ablation(&widths) {
        t.row(&[
            r.name.into(),
            r.n.to_string(),
            r.gates.to_string(),
            f(r.area, 1),
            f(r.delay, 1),
        ]);
    }
    t.print();

    let mut t = Table::new(
        "F1/F9/F12 — datapath blocks (N=256 accumulation)",
        &["block", "n", "comb area", "reg area", "total", "delay", "rel"],
    );
    for r in report::block_comparison(&widths, 256) {
        t.row(&[
            r.name.into(),
            r.n.to_string(),
            f(r.comb_area, 1),
            f(r.reg_area, 1),
            f(r.total_area, 1),
            f(r.critical_path, 1),
            f(r.rel_area, 3),
        ]);
    }
    t.print();
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let n = args.get_usize("size", 16)?;
    let seed = args.get_u64("seed", 42)?;
    let mut rng = Rng::new(seed);

    let a = Matrix::random(&mut rng, n, n, -100, 100);
    let b = Matrix::random(&mut rng, n, n, -100, 100);
    let want = fairsquare::linalg::matmul::matmul_direct(&a, &b).0;

    let mut t = Table::new(
        &format!("Fig. 2/3 + 4/5 — {n}×{n}×{n} on cycle-accurate engines"),
        &["engine", "cycles", "PE ops", "util", "exact"],
    );
    for (name, kind) in [("systolic/MAC", sim::systolic::PeKind::Mac),
                         ("systolic/square", sim::systolic::PeKind::Square)] {
        let run = sim::systolic::systolic_matmul(kind, &a, &b);
        t.row(&[
            name.into(),
            run.stats.cycles.to_string(),
            run.stats.pe_ops.to_string(),
            f(run.stats.utilization(), 3),
            (run.c == want).to_string(),
        ]);
    }
    for (name, kind) in [("tensorcore/MAC", sim::tensor_core::TcKind::Mac),
                         ("tensorcore/square", sim::tensor_core::TcKind::Square)] {
        let tn = 4.min(n);
        let (c, stats, _) = sim::tensor_core::tiled_matmul(kind, &a, &b, tn);
        t.row(&[
            name.into(),
            stats.cycles.to_string(),
            stats.pe_ops.to_string(),
            f(stats.utilization(), 3),
            (c == want).to_string(),
        ]);
    }
    t.print();

    // FIR engines
    let taps = rng.vec_i64(8, -50, 50);
    let signal = rng.vec_i64(n * 16, -100, 100);
    let direct = fairsquare::linalg::conv::conv1d_direct(&taps, &signal).0;
    let mut t = Table::new(
        &format!("Fig. 7/8 — 8-tap FIR over {} samples", signal.len()),
        &["engine", "squares", "mults", "exact"],
    );
    {
        let mut e = sim::conv::DirectFir::new(taps.clone());
        let out = sim::conv::run_fir(|x| e.step(x), &signal);
        t.row(&["direct (7a)".into(), "0".into(), e.ops().mults.to_string(),
                (out == direct).to_string()]);
        let mut e = sim::conv::TransposedFir::new(taps.clone());
        let out = sim::conv::run_fir(|x| e.step(x), &signal);
        t.row(&["transposed (7b)".into(), "0".into(), e.ops().mults.to_string(),
                (out == direct).to_string()]);
        let mut e = sim::conv::SquareFir::new(taps.clone());
        let out = sim::conv::run_fir(|x| e.step(x), &signal);
        t.row(&["square (8)".into(), e.ops().squares.to_string(), "0".into(),
                (out == direct).to_string()]);
    }
    t.print();
    Ok(())
}

fn errors(_args: &Args) -> Result<()> {
    let rows = error::matmul_error_sweep(&[16, 64, 256], &[1.0, 100.0], 7);
    let mut t = Table::new(
        "E5 — float error vs f64 ground truth (relative Frobenius)",
        &["n", "scale", "direct f32", "square f32", "square f64", "amplify"],
    );
    for r in rows {
        t.row(&[
            r.n.to_string(),
            f(r.scale, 1),
            format!("{:.3e}", r.direct_f32.rel_fro),
            format!("{:.3e}", r.square_f32.rel_fro),
            format!("{:.3e}", r.square_f64.rel_fro),
            f(r.amplification, 2),
        ]);
    }
    t.print();
    println!("note: the paper treats the rewrite as exact; in floating point the");
    println!("cancellation in eq. (4) costs ~½log2(n) extra bits (amplification");
    println!("grows like sqrt(n): ≈4x at n=16, ≈16x at n=256) — see DESIGN.md §6.");
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    if let Some(listen) = args.get("listen") {
        return serve_listen(args, listen);
    }
    let requests = args.get_usize("requests", 256)?;
    let rps = args.get_u64("rps", 2_000)? as f64;
    let shadow_wanted = !args.has("no-shadow");
    let workers = args.get_usize("workers", 1)?.max(1);
    let routing = match args.get_or("steal", "on") {
        "on" => Routing::Steal,
        "off" => Routing::Fifo,
        other => bail!("--steal expects on|off, got {other:?}"),
    };
    let native = args.has("native");
    let model = args
        .get_or("model", if native { "dense" } else { "mlp_square" })
        .to_string();

    // tile-granular whale forking (§3.3) and the skewed request mix.
    // Same convention as the conv geometry below — no clamping: an
    // explicit 0 on any of these knobs is a typed error, never a silent 1
    // (or a silent "off").
    let tile_threshold = args.get_u64("tile-threshold", 0)?;
    if args.get("tile-threshold").is_some() && tile_threshold == 0 {
        bail!("--tile-threshold must be >= 1 cost unit; omit the flag to disable tiling");
    }
    let tile_rows = args.get_usize("tile", 8)?;
    if tile_rows == 0 {
        bail!("--tile must be >= 1 row per tile");
    }
    let heavy_frac = args.get_usize("heavy-frac", 0)?;
    if args.get("heavy-frac").is_some() && heavy_frac == 0 {
        bail!("--heavy-frac must be >= 1 (every N-th request is heavy); omit for all-light");
    }
    let heavy_size = args.get_u64("heavy-size", 32)?;
    if heavy_size == 0 {
        bail!("--heavy-size must be >= 1 light-row cost unit");
    }
    if heavy_size > u32::MAX as u64 {
        bail!("--heavy-size {heavy_size} exceeds the executor's u32 cost range");
    }
    let heavy_mix = heavy_frac > 0;
    if heavy_mix && !(native && model == "dense") {
        bail!(
            "--heavy-frac shapes the dense native mix (the cost-model \
             executor reads the heavy tag); use --native --model dense"
        );
    }
    let tiling = if tile_threshold > 0 {
        if !native {
            bail!("--tile-threshold requires --native (the PJRT path is untiled)");
        }
        Some(TileConfig {
            threshold: tile_threshold,
            tile_rows,
            heavy_cost: heavy_size,
        })
    } else {
        None
    };

    // the qnn model serves int64 rows, so it drives its own typed lane
    // (same pool, same knobs, different scalar)
    if native && model == "qnn" {
        return serve_qnn(args, requests, rps, shadow_wanted, workers, routing, tiling);
    }

    // complex requests are plane-split QPSK rows, conv requests are NCHW
    // images with --in-ch planes, everything else serves MNIST-like
    // vectors; sized to match the executors built below
    let complex_subcarriers = 64usize;
    let complex_rows = native && model == "complex";
    // no clamping: a zero --in-ch, --stride or --dilation must surface as
    // the typed InvalidConvSpec error the subsystem produces, not run
    // silently as 1
    let conv_rows = native && model == "conv";
    let in_ch = args.get_usize("in-ch", 1)?;
    let conv_stride = args.get_usize("stride", 1)?;
    let conv_pad = args.get_usize("pad", 0)?;
    let conv_dilation = args.get_usize("dilation", 1)?;

    let srv = if native {
        // native path: the blocked multi-threaded square-kernel engine
        // serves a random-but-deterministic model in-process, sharded
        // across `workers` threads that share one prepared operand
        // (corrections computed once), shadowed by its direct twin
        let threads = args.get_usize("threads", fairsquare::linalg::engine::max_threads())?;
        // the --threads budget is the whole pool's: each worker's engine
        // gets an even share so W workers don't oversubscribe W× the cores
        let per_worker_threads = (threads / workers).max(1);
        let cfg =
            fairsquare::linalg::engine::EngineConfig::with_threads(per_worker_threads);
        let shadow_every = if shadow_wanted { 8 } else { 0 };
        let shadow_str = if shadow_wanted { "direct twin" } else { "off" };
        let steal_str = if routing == Routing::Steal { "on" } else { "off" };

        match model.as_str() {
            "dense" => {
                let mut rng = Rng::new(0xE6);
                let weights =
                    Matrix::from_fn(784, 10, |_, _| (rng.normal() * 0.05) as f32);
                // report the parallelism this batch shape actually gets:
                // the engine caps workers by useful work, so small models
                // run fewer threads than requested no matter the knob
                let effective = fairsquare::linalg::engine::effective_threads(
                    per_worker_threads, 32, 784, 10,
                );
                println!(
                    "starting server: native dense square-kernel model 784→10, \
                     {workers} worker(s) ({per_worker_threads} engine threads \
                     each, {effective} effective per 32-row batch) \
                     steal={steal_str} shadow={shadow_str}"
                );
                let (prepared, _prep_ops) =
                    fairsquare::linalg::engine::PreparedB::new_shared(weights);
                let shadow_w = prepared.matrix().clone();
                // the cost-model wrapper is a no-op at cost 1 (light mix)
                // and prices heavy-tagged rows at --heavy-size when the
                // whale mix is on — same executor type either way, so the
                // pool factory stays monomorphic
                let skew_cost = if heavy_mix { heavy_size as u32 } else { 1 };
                fairsquare::coordinator::InferenceServer::start_tiled(
                    32,
                    Duration::from_millis(2),
                    1024,
                    shadow_every,
                    workers,
                    routing,
                    tiling,
                    move |_wid| {
                        Ok(fairsquare::coordinator::SkewedKernelExecutor::new(
                            fairsquare::coordinator::SquareKernelExecutor::from_shared(
                                prepared.clone(),
                                32,
                                cfg.clone(),
                            ),
                            skew_cost,
                        ))
                    },
                    move |_wid| {
                        if shadow_wanted {
                            Ok(Some(fairsquare::coordinator::DirectKernelExecutor::new(
                                shadow_w.clone(),
                                32,
                            )))
                        } else {
                            Ok(None)
                        }
                    },
                )?
            }
            "conv" => {
                // a CNN layer over NCHW traffic: 8 filters of in_ch×3×3
                // with the requested stride/padding/dilation on
                // in_ch×28×28 images, one blocked square matmul per batch
                // via the generalized im2col lowering; bank corrections
                // prepared once for the whole pool, per-worker workspace
                // arenas reusing all lowering scratch across batches
                let spec = fairsquare::linalg::engine::ConvSpec::new(in_ch, 8, 3, 3)
                    .with_stride(conv_stride)
                    .with_padding(conv_pad)
                    .with_dilation(conv_dilation);
                let (out_h, out_w) = spec.output_shape(28, 28)?;
                let mut rng = Rng::new(0xC0);
                let filters: Vec<f32> = (0..spec.bank_len())
                    .map(|_| (rng.normal() * 0.2) as f32)
                    .collect();
                println!(
                    "starting server: native conv model (8 filters \
                     {in_ch}×3×3 over {in_ch}×28×28 NCHW, stride \
                     {conv_stride}, pad {conv_pad}, dilation \
                     {conv_dilation} → {out_h}×{out_w} maps, im2col \
                     lowering), {workers} worker(s) \
                     ({per_worker_threads} engine threads each) \
                     steal={steal_str} shadow={shadow_str}"
                );
                let (bank, _prep_ops) =
                    fairsquare::linalg::engine::PreparedConvBank::new_nchw_shared(
                        &filters, spec,
                    )?;
                let shadow_bank = bank.clone();
                let shadow_cfg = cfg.clone();
                fairsquare::coordinator::InferenceServer::start_tiled(
                    16,
                    Duration::from_millis(2),
                    1024,
                    shadow_every,
                    workers,
                    routing,
                    tiling,
                    move |_wid| {
                        fairsquare::coordinator::Conv2dExecutor::from_shared(
                            bank.clone(),
                            28,
                            28,
                            16,
                            cfg.clone(),
                        )
                    },
                    move |_wid| {
                        if shadow_wanted {
                            Ok(Some(
                                fairsquare::coordinator::Conv2dDirectExecutor::from_shared(
                                    shadow_bank.clone(),
                                    28,
                                    28,
                                    16,
                                    shadow_cfg.clone(),
                                )?,
                            ))
                        } else {
                            Ok(None)
                        }
                    },
                )?
            }
            "complex" => {
                // a DSP beamforming layer over QPSK traffic: plane-split
                // 64→16 complex matmul via the three-pass CPM3 lowering;
                // the three derived operands and their correction caches
                // prepared once for the whole pool
                let (n, p) = (complex_subcarriers, 16usize);
                let mut rng = Rng::new(0xC3);
                let y_re =
                    Matrix::from_fn(n, p, |_, _| (rng.normal() * 0.1) as f32);
                let y_im =
                    Matrix::from_fn(n, p, |_, _| (rng.normal() * 0.1) as f32);
                println!(
                    "starting server: native complex CPM3 model {n}→{p} \
                     (plane-split, 3 square passes), {workers} worker(s) \
                     ({per_worker_threads} engine threads each) \
                     steal={steal_str} shadow={shadow_str}"
                );
                let planes = fairsquare::linalg::engine::CPlanes::new(
                    y_re.clone(),
                    y_im.clone(),
                )?;
                let (prepared, _prep_ops) =
                    fairsquare::linalg::engine::PreparedCpm3::new_shared(&planes)?;
                let shadow_cfg = cfg.clone();
                fairsquare::coordinator::InferenceServer::start_tiled(
                    32,
                    Duration::from_millis(2),
                    1024,
                    shadow_every,
                    workers,
                    routing,
                    tiling,
                    move |_wid| {
                        fairsquare::coordinator::ComplexMatmulExecutor::from_shared(
                            prepared.clone(),
                            32,
                            cfg.clone(),
                        )
                    },
                    move |_wid| {
                        if shadow_wanted {
                            Ok(Some(
                                fairsquare::coordinator::ComplexMatmulDirectExecutor::new(
                                    y_re.clone(),
                                    y_im.clone(),
                                    32,
                                    shadow_cfg.clone(),
                                )?,
                            ))
                        } else {
                            Ok(None)
                        }
                    },
                )?
            }
            other => bail!(
                "unknown native model {other:?}; native models are \
                 dense, conv, complex, qnn"
            ),
        }
    } else {
        if workers > 1 {
            bail!(
                "the PJRT serving path is single-worker (its engine is not \
                 `Send`); use --native for --workers {workers}"
            );
        }
        let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
        let model = model.clone();
        let baseline = model.replace("_square", "_direct");
        let shadow = shadow_wanted && baseline != model;

        println!("starting server: model={model} shadow={}",
                 if shadow { baseline.as_str() } else { "off" });
        let dir2 = dir.clone();
        let model2 = model.clone();
        let baseline2 = baseline.clone();
        // single worker, so routing only picks the (FIFO either way)
        // service order — but the knob is honored, not silently dropped
        InferenceServer::start_routed(
            32,
            Duration::from_millis(2),
            1024,
            if shadow { 8 } else { 0 },
            1,
            routing,
            move |_wid| PjrtExecutor::new(&dir2, &model2),
            move |_wid| {
                if shadow {
                    Ok(Some(PjrtExecutor::new(&dir, &baseline2)?))
                } else {
                    Ok(None)
                }
            },
        )?
    };

    let mut gen = WorkloadGen::new(0xE6);
    let gaps = gen.arrival_gaps_us(requests, rps);
    // the CLI whale mix and the e2e bench replay the SAME generator path
    // (WorkloadGen::skewed_stream): every --heavy-frac'th request carries
    // the heavy tag the cost-model executor reads
    let mut skewed = heavy_mix
        .then(|| gen.skewed_stream(requests, 784, heavy_frac).into_iter());
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for gap in gaps {
        std::thread::sleep(Duration::from_micros(gap.min(5_000)));
        let input = if let Some(stream) = skewed.as_mut() {
            stream.next().expect("skewed stream is sized to `requests`")
        } else if complex_rows {
            gen.qpsk_row(complex_subcarriers)
        } else if conv_rows {
            gen.nchw_image(in_ch, 28, 28)
        } else {
            gen.mnist_like()
        };
        pending.push(srv.submit(input)?);
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let stats = srv.shutdown()?;
    print_serve_report(&stats, ok, requests, wall)
}

/// `serve --native --model qnn`: the int64 serving lane — the same
/// pool/knob surface as the f32 models, but the fused int8 pipeline
/// executor behind it and quantized MNIST-like rows in front of it.
fn serve_qnn(
    args: &Args,
    requests: usize,
    rps: f64,
    shadow_wanted: bool,
    workers: usize,
    routing: Routing,
    tiling: Option<TileConfig>,
) -> Result<()> {
    let threads = args.get_usize("threads", fairsquare::linalg::engine::max_threads())?;
    let per_worker_threads = (threads / workers).max(1);
    let cfg = fairsquare::linalg::engine::EngineConfig::with_threads(per_worker_threads);
    let shadow_every = if shadow_wanted { 8 } else { 0 };
    let steal_str = if routing == Routing::Steal { "on" } else { "off" };
    let shadow_str = if shadow_wanted { "scalar QMlp oracle" } else { "off" };
    println!(
        "starting server: native qnn int8 model 784→64→10 (requant fused \
         into the blocked square pipeline, exact integer logits), \
         {workers} worker(s) ({per_worker_threads} engine threads each) \
         steal={steal_str} shadow={shadow_str}"
    );
    let mlp = ingress::qnn_model();
    let (prepared, _prep_ops) = fairsquare::qnn::PreparedQnn::new_shared(&mlp);
    let shadow_mlp = Arc::new(mlp);
    let srv: InferenceServer<i64> = InferenceServer::start_tiled(
        32,
        Duration::from_millis(2),
        1024,
        shadow_every,
        workers,
        routing,
        tiling,
        move |_wid| Ok(QnnExecutor::from_shared(prepared.clone(), 32, cfg.clone())),
        move |_wid| {
            if shadow_wanted {
                Ok(Some(QnnScalarExecutor::new(shadow_mlp.clone(), 32)))
            } else {
                Ok(None)
            }
        },
    )?;

    let mut gen = WorkloadGen::new(0xE6);
    let gaps = gen.arrival_gaps_us(requests, rps);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for gap in gaps {
        std::thread::sleep(Duration::from_micros(gap.min(5_000)));
        pending.push(srv.submit(gen.quant_mnist_like())?);
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let stats = srv.shutdown()?;
    print_serve_report(&stats, ok, requests, wall)
}

/// The pooled + per-worker E6 serving report, shared by the f32 and
/// the int64 serving lanes (the stats are dtype-independent).
fn print_serve_report(
    stats: &ServerStats,
    ok: usize,
    requests: usize,
    wall: Duration,
) -> Result<()> {
    let l = stats.latency;
    let mut t = Table::new("E6 — serving report (pooled)", &["metric", "value"]);
    t.row(&["workers".into(), stats.workers.to_string()]);
    t.row(&["completed".into(), format!("{ok}/{requests}")]);
    t.row(&["wall time".into(), format!("{wall:.2?}")]);
    t.row(&["throughput".into(),
            format!("{:.0} rows/s", ok as f64 / wall.as_secs_f64())]);
    t.row(&["mean batch".into(), f(stats.mean_batch, 2)]);
    t.row(&["p50 latency".into(), format!("{:.0} µs", l.p50_us)]);
    t.row(&["p95 latency".into(), format!("{:.0} µs", l.p95_us)]);
    t.row(&["p99 latency".into(), format!("{:.0} µs", l.p99_us)]);
    t.row(&["shadow checks".into(), stats.shadow_checks.to_string()]);
    t.row(&["shadow failures".into(), stats.shadow_failures.to_string()]);
    t.row(&["shadow errors".into(), stats.shadow_errors.to_string()]);
    t.row(&["stolen batches".into(), stats.stolen_batches.to_string()]);
    t.row(&["steal attempts".into(), stats.steal_attempts.to_string()]);
    t.row(&["tiled requests".into(), stats.tiled_requests.to_string()]);
    t.row(&["tiles executed".into(), stats.tiles_executed.to_string()]);
    t.row(&["rejected".into(), stats.rejected.to_string()]);
    t.row(&["lost workers".into(), stats.lost_workers.to_string()]);
    t.print();

    if stats.workers > 1 {
        let mut t = Table::new(
            "E6 — per-worker view",
            &["worker", "batches", "stolen", "tiles", "rows", "mean batch",
              "p50 µs", "p99 µs"],
        );
        for w in &stats.per_worker {
            t.row(&[
                w.worker.to_string(),
                w.batches.to_string(),
                w.stolen_batches.to_string(),
                w.tiles_executed.to_string(),
                w.rows.to_string(),
                f(w.mean_batch, 2),
                format!("{:.0}", w.latency.p50_us),
                format!("{:.0}", w.latency.p99_us),
            ]);
        }
        t.print();
    }

    if stats.shadow_failures > 0 {
        bail!("shadow verification failed");
    }
    Ok(())
}

/// `serve --listen`: the network serving mode — bind the TCP ingress,
/// register the requested native models (each model's §3/§9 corrections
/// hoisted once at registration, shared by its whole worker pool),
/// drive the request load over real sockets from concurrent client
/// connections, and print the conservation-checked pooled + per-model
/// report.
fn serve_listen(args: &Args, listen: &str) -> Result<()> {
    // knobs that only shape the in-process demo paths are refused, not
    // ignored — the same no-silent-fixup convention as the conv geometry
    for (flag, hint) in [
        ("model", "pick the served set with --models NAMES"),
        ("artifacts", "the network front door serves the native models"),
        ("tile-threshold", "tiling is an in-process serving knob"),
        ("heavy-frac", "the whale mix drives the in-process demo"),
        ("in-ch", "the network conv model is fixed at 1×28×28 NCHW"),
    ] {
        if args.get(flag).is_some() {
            bail!("--{flag} does not apply to --listen ({hint})");
        }
    }
    let addr = ingress::parse_listen_addr(listen)?;
    let names = ingress::parse_model_list(args.get_or("models", "dense,conv,complex,qnn"))?;
    let requests = args.get_usize("requests", 96)?;
    let rps = args.get_u64("rps", 2_000)? as f64;
    let clients = args.get_usize("clients", 3)?;
    if clients == 0 {
        bail!("--clients must be >= 1 connection");
    }
    let workers = args.get_usize("workers", 2)?.max(1);
    let routing = match args.get_or("steal", "on") {
        "on" => Routing::Steal,
        "off" => Routing::Fifo,
        other => bail!("--steal expects on|off, got {other:?}"),
    };
    let cost_budget = args.get_u64("cost-budget", 0)?;
    if args.get("cost-budget").is_some() && cost_budget == 0 {
        bail!("--cost-budget must be >= 1 cost unit; omit the flag for the count bound only");
    }
    let cost_budget = if cost_budget == 0 { u64::MAX } else { cost_budget };
    let threads = args.get_usize("threads", fairsquare::linalg::engine::max_threads())?;
    let per_worker_threads = (threads / workers).max(1);
    let shadow_every = if args.has("no-shadow") { 0 } else { 8 };

    let cfg = ingress::NativeServing {
        workers,
        routing,
        shadow_every,
        engine_threads: per_worker_threads,
        queue_depth: 1024,
        cost_budget,
        max_wait: Duration::from_millis(2),
    };
    let mut reg = ingress::ModelRegistry::new();
    for name in &names {
        ingress::register_native(&mut reg, name, &cfg)?;
    }
    let server = ingress::IngressServer::bind(&addr.to_string(), reg)?;
    let local = server.local_addr();
    println!(
        "ingress listening on {local}: models [{}], {workers} worker(s)/model \
         ({per_worker_threads} engine threads each), steal={}, shadow={}, \
         driving {requests} requests from {clients} client connection(s)",
        names.join(", "),
        if routing == Routing::Steal { "on" } else { "off" },
        if shadow_every > 0 { "direct twin" } else { "off" },
    );

    // drive the load over real sockets: each client thread owns one TCP
    // connection and walks the model list round-robin, offset by its
    // index so concurrent in-flight requests mix models
    let t0 = std::time::Instant::now();
    let mut drivers = Vec::with_capacity(clients);
    for c in 0..clients {
        let names = names.clone();
        let n = requests / clients + usize::from(c < requests % clients);
        let per_client_rps = (rps / clients as f64).max(1.0);
        drivers.push(std::thread::spawn(move || -> Result<(u64, u64)> {
            let mut gen = WorkloadGen::new(0xE8 + c as u64);
            let gaps = gen.arrival_gaps_us(n, per_client_rps);
            let mut client = ingress::TcpClient::connect(local)?;
            let (mut ok, mut rejected) = (0u64, 0u64);
            for (k, gap) in gaps.into_iter().enumerate() {
                std::thread::sleep(Duration::from_micros(gap.min(5_000)));
                let name = &names[(c + k) % names.len()];
                // the qnn model speaks the int64 wire lane; everything
                // else rides f32 — same client, dtype picked per model
                let outcome = if name == "qnn" {
                    let row = ingress::sample_input_i64(&mut gen, name)?;
                    client.infer(name, &row)?.map(|_out| ())
                } else {
                    let row = ingress::sample_input(&mut gen, name)?;
                    client.infer(name, &row)?.map(|_out| ())
                };
                match outcome {
                    Ok(()) => ok += 1,
                    Err(_rejection) => rejected += 1,
                }
            }
            Ok((ok, rejected))
        }));
    }
    let (mut ok, mut rejected) = (0u64, 0u64);
    for d in drivers {
        let (o, r) = d.join().map_err(|_| anyhow!("a client driver panicked"))??;
        ok += o;
        rejected += r;
    }
    let wall = t0.elapsed();

    let report = server.shutdown()?;
    report.check_conservation()?;
    let totals = report.totals;
    let mut t = Table::new("E8 — ingress report (pooled)", &["metric", "value"]);
    t.row(&["models".into(), names.join(", ")]);
    t.row(&["client connections".into(), clients.to_string()]);
    t.row(&["client ok / rejected".into(), format!("{ok} / {rejected}")]);
    t.row(&["submitted".into(), totals.submitted.to_string()]);
    t.row(&["served".into(), totals.served.to_string()]);
    t.row(&["rejected".into(), totals.rejected.to_string()]);
    t.row(&["errored".into(), totals.errored.to_string()]);
    t.row(&["disconnects".into(), totals.disconnects.to_string()]);
    t.row(&["unroutable".into(), report.unroutable.to_string()]);
    t.row(&["wall time".into(), format!("{wall:.2?}")]);
    t.row(&[
        "throughput".into(),
        format!("{:.0} rows/s", totals.served as f64 / wall.as_secs_f64()),
    ]);
    t.print();

    let mut t = Table::new(
        "E8 — per-model view (sums == pooled totals, checked)",
        &["model", "cost", "in→out", "submitted", "served", "rejected",
          "mean batch", "p50 µs", "p99 µs"],
    );
    for m in &report.per_model {
        t.row(&[
            m.name.clone(),
            m.row_cost.to_string(),
            format!("{}→{}", m.artifact.args[0].shape[1], m.artifact.outputs[0].shape[1]),
            m.ingress.submitted.to_string(),
            m.ingress.served.to_string(),
            m.ingress.rejected.to_string(),
            f(m.server.mean_batch, 2),
            format!("{:.0}", m.server.latency.p50_us),
            format!("{:.0}", m.server.latency.p99_us),
        ]);
    }
    t.print();

    let shadow_failures: u64 = report.per_model.iter().map(|m| m.server.shadow_failures).sum();
    if shadow_failures > 0 {
        bail!("shadow verification failed");
    }
    Ok(())
}

fn list(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let reg = fairsquare::runtime::Registry::load(&dir)?;
    let mut t = Table::new("artifacts", &["name", "args", "outputs"]);
    for e in reg.entries() {
        let fmt_specs = |specs: &[fairsquare::runtime::TensorSpec]| {
            specs
                .iter()
                .map(|s| format!("{:?}", s.shape))
                .collect::<Vec<_>>()
                .join(" ")
        };
        t.row(&[e.name.clone(), fmt_specs(&e.args), fmt_specs(&e.outputs)]);
    }
    t.print();
    Ok(())
}
