//! First-party CLI argument parsing (offline substitute for clap).
//!
//! Flags are `--name value` or `--name` (boolean); the first bare word is
//! the subcommand. Strict: unknown flags are errors, so typos fail fast.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) with a schema of known
    /// value-flags and boolean-flags.
    pub fn parse_from<I: IntoIterator<Item = String>>(
        argv: I,
        value_flags: &[&str],
        bool_flags: &[&str],
    ) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.into_iter();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if bool_flags.contains(&name) {
                    out.bools.push(name.to_string());
                } else if value_flags.contains(&name) {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    out.flags.insert(name.to_string(), v);
                } else {
                    bail!("unknown flag --{name}");
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                bail!("unexpected positional argument {tok:?}");
            }
        }
        Ok(out)
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse(value_flags: &[&str], bool_flags: &[&str]) -> Result<Self> {
        Self::parse_from(std::env::args().skip(1), value_flags, bool_flags)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_command_flags_and_bools() {
        let a = Args::parse_from(
            argv("serve --model mlp_square --requests 100 --verbose"),
            &["model", "requests"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get("model"), Some("mlp_square"));
        assert_eq!(a.get_usize("requests", 0).unwrap(), 100);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(Args::parse_from(argv("x --nope 1"), &["model"], &[]).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse_from(argv("x --model"), &["model"], &[]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_from(argv("bench"), &["n"], &[]).unwrap();
        assert_eq!(a.get_or("n", "64"), "64");
        assert_eq!(a.get_usize("n", 64).unwrap(), 64);
    }

    #[test]
    fn bad_integer_is_error() {
        let a = Args::parse_from(argv("x --n abc"), &["n"], &[]).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }
}
