//! Blocked 2-D convolution: the generalized im2col lowering onto the
//! square-matmul core.
//!
//! The reference [`conv2d_square`](crate::linalg::conv::conv2d_square)
//! makes the paper's §5 op-count claims auditable one filter at a time;
//! this module makes convolution *fast in software* the way the tiled
//! hardware papers lower it: extract the patch matrix once
//! ([`im2col_nchw`]), then run one cache-blocked, threaded square matmul
//! against the whole filter bank. Any [`ConvSpec`] geometry lowers the
//! same way — multi-channel NCHW, stride, zero-padding and dilation are
//! all absorbed by the extraction, so the matmul core never knows they
//! exist: the lowering is always a `(K, T, F)` square product with
//! `K = batch·out_h·out_w` output pixels, `T = C·kh·kw` taps and `F`
//! filters.
//!
//! [`PreparedConvBank`] is the §3 constant-matrix case for CNNs: a fixed
//! filter bank's column corrections `Sb_f = −Σ_t b_tf²` are computed once
//! per model ([`PreparedB`]) and amortised across every image, every
//! filter and — via `new_shared` — every worker of a serving pool.
//! [`PreparedConvBank::apply_batch_ws`] is the steady-state serving form:
//! the patch matrix, GEMM output, row corrections and scattered serving
//! buffer are all [`EngineWorkspace`] checkouts, so a warmed batch
//! performs zero heap allocations (single-threaded engine config).
//!
//! Ledgers are hoisted and shape-deterministic: the lowering *is* a
//! `(K, T, F)` square matmul, so its ledger is exactly
//! [`square_matmul_ledger`]`(K, T, F)` (one-shot) or
//! [`square_matmul_const_b_ledger`]`(K, T, F)` (prepared bank), asserted
//! equal to per-element counting by the tests below — padding zeros flow
//! through the same window squares as real samples, keeping the ledger a
//! function of the shape alone.

use std::sync::Arc;

use super::super::conv::conv2d_output_shape;
use super::super::counts::OpCounts;
use super::super::matrix::Matrix;
use super::super::LinalgError;
use super::blocked::{
    matmul_direct_blocked_into, matmul_square_blocked, matmul_square_prepared,
    matmul_square_prepared_into, square_matmul_const_b_ledger, square_matmul_ledger,
    EngineConfig, PreparedB,
};
use super::im2col::{
    bank_matrix, im2col, im2col_nchw, im2col_nchw_into, nchw_bank_matrix,
    scatter_bank_output, scatter_bank_output_into,
};
use super::spec::ConvSpec;
use super::workspace::EngineWorkspace;
use super::SquareScalar;

/// Blocked (and, with `cfg.threads > 1`, threaded) square-based 2-D valid
/// correlation of one kernel over one image — the im2col lowering of
/// eq. (13). Values are identical to
/// [`conv2d_direct`](crate::linalg::conv::conv2d_direct); the ledger is
/// the lowering's own: a `(K, T, 1)` square matmul.
pub fn conv2d_square_blocked<T: SquareScalar>(
    w: &Matrix<T>,
    x: &Matrix<T>,
    cfg: &EngineConfig,
) -> Result<(Matrix<T>, OpCounts), LinalgError> {
    let (out_h, out_w) = conv2d_output_shape(w.rows, w.cols, x.rows, x.cols)?;
    let a = im2col(x, w.rows, w.cols);
    let b = Matrix::from_vec(w.rows * w.cols, 1, w.data().to_vec());
    let (c, ops) = matmul_square_blocked(&a, &b, cfg);
    debug_assert_eq!(ops, square_matmul_ledger(out_h * out_w, w.rows * w.cols, 1));
    Ok((Matrix::from_vec(out_h, out_w, c.data().to_vec()), ops))
}

/// A constant CNN filter bank, lowered and prepared once: the flattened
/// `(C·kh·kw) × filters` weight matrix with its column corrections cached
/// ([`PreparedB`]) and the full [`ConvSpec`] geometry it was built for.
/// Build per model, reuse for every image — and share across a worker
/// pool via [`PreparedConvBank::new_shared`] /
/// [`PreparedConvBank::new_nchw_shared`].
#[derive(Debug, Clone)]
pub struct PreparedConvBank<T> {
    spec: ConvSpec,
    pb: PreparedB<T>,
}

impl<T: SquareScalar> PreparedConvBank<T> {
    /// Validate and prepare a single-channel stride-1 unpadded bank from
    /// per-filter kernel matrices — the PR 3 constructor, now a special
    /// case of [`Self::new_nchw`]. The returned ledger is the one-time
    /// preparation cost: `T·F` correction squares (§3).
    pub fn new(filters: &[Matrix<T>]) -> Result<(Self, OpCounts), LinalgError> {
        if filters.is_empty() {
            return Err(LinalgError::EmptyInput { what: "filter bank" });
        }
        let (kh, kw) = (filters[0].rows, filters[0].cols);
        if kh == 0 || kw == 0 {
            return Err(LinalgError::EmptyInput { what: "kernel" });
        }
        for f in filters {
            if (f.rows, f.cols) != (kh, kw) {
                return Err(LinalgError::ShapeMismatch {
                    what: "filter bank kernel",
                    expected: (kh, kw),
                    got: (f.rows, f.cols),
                });
            }
        }
        let spec = ConvSpec::new(1, filters.len(), kh, kw);
        let (pb, prep_ops) = PreparedB::new(bank_matrix(filters));
        Ok((Self { spec, pb }, prep_ops))
    }

    /// Validate and prepare a generalized NCHW bank: `filters_flat` is
    /// the `[filter][channel][kh][kw]` buffer of `spec.bank_len()`
    /// values, `spec` carries channels/stride/padding/dilation. The
    /// returned ledger is the one-time §3 cost: `T·F = C·kh·kw·F`
    /// correction squares.
    pub fn new_nchw(filters_flat: &[T], spec: ConvSpec) -> Result<(Self, OpCounts), LinalgError> {
        spec.validate()?;
        if filters_flat.len() != spec.bank_len() {
            return Err(LinalgError::ShapeMismatch {
                what: "filter bank buffer",
                expected: (spec.out_channels, spec.taps()),
                got: (1, filters_flat.len()),
            });
        }
        let (pb, prep_ops) = PreparedB::new(nchw_bank_matrix(filters_flat, &spec));
        Ok((Self { spec, pb }, prep_ops))
    }

    /// Prepare and wrap for sharing across a serving pool: the bank's
    /// corrections are computed exactly once no matter how many workers
    /// serve the model.
    pub fn new_shared(filters: &[Matrix<T>]) -> Result<(Arc<Self>, OpCounts), LinalgError> {
        let (bank, ops) = Self::new(filters)?;
        Ok((Arc::new(bank), ops))
    }

    /// [`Self::new_nchw`], wrapped for a pool.
    pub fn new_nchw_shared(
        filters_flat: &[T],
        spec: ConvSpec,
    ) -> Result<(Arc<Self>, OpCounts), LinalgError> {
        let (bank, ops) = Self::new_nchw(filters_flat, spec)?;
        Ok((Arc::new(bank), ops))
    }

    /// The full geometry this bank was prepared for.
    pub fn spec(&self) -> &ConvSpec {
        &self.spec
    }

    pub fn kernel_h(&self) -> usize {
        self.spec.kernel_h
    }

    pub fn kernel_w(&self) -> usize {
        self.spec.kernel_w
    }

    /// Input planes per image (the C of NCHW).
    pub fn in_channels(&self) -> usize {
        self.spec.in_channels
    }

    /// Taps per output pixel (`C·kh·kw` — the contraction dimension).
    pub fn taps(&self) -> usize {
        self.spec.taps()
    }

    pub fn filters(&self) -> usize {
        self.pb.out_features()
    }

    /// The lowered `(C·kh·kw) × filters` weight matrix (for direct-twin
    /// shadow executors that want the exact same weights).
    pub fn matrix(&self) -> &Matrix<T> {
        self.pb.matrix()
    }

    /// The prepared lowered bank as a [`PreparedB`] — the constant-B
    /// operand of the §3.3 tile entry points
    /// ([`super::blocked::matmul_square_prepared_tile_into`]), so a tiled
    /// conv executor can run disjoint post-im2col row partitions against
    /// the bank's once-per-model `Sb` corrections.
    pub fn prepared(&self) -> &PreparedB<T> {
        &self.pb
    }

    /// Validated output map shape for an `in_h×in_w` (per-channel) input.
    pub fn output_shape(&self, in_h: usize, in_w: usize) -> Result<(usize, usize), LinalgError> {
        self.spec.output_shape(in_h, in_w)
    }

    /// Convolve the whole bank over one single-plane image: one
    /// `(K, T, F)` square matmul against the prepared weights, split back
    /// into one `out_h×out_w` map per filter. Convenience for
    /// single-channel banks (multi-channel banks take NCHW batches
    /// through [`Self::apply_batch`]). The per-call ledger drops the
    /// `T·F` correction squares [`Self::new`] already paid.
    pub fn apply(
        &self,
        x: &Matrix<T>,
        cfg: &EngineConfig,
    ) -> Result<(Vec<Matrix<T>>, OpCounts), LinalgError> {
        if self.spec.in_channels != 1 {
            return Err(LinalgError::ShapeMismatch {
                what: "apply() image planes (multi-channel banks take NCHW batches)",
                expected: (1, 1),
                got: (self.spec.in_channels, 1),
            });
        }
        let (out_h, out_w) = self.output_shape(x.rows, x.cols)?;
        let (flat, ops) = self.apply_batch(x.data(), 1, x.rows, x.cols, cfg)?;
        let k_out = out_h * out_w;
        let maps = (0..self.filters())
            .map(|f| {
                Matrix::from_vec(out_h, out_w, flat[f * k_out..(f + 1) * k_out].to_vec())
            })
            .collect();
        Ok((maps, ops))
    }

    /// Convolve the bank over a batch of flattened NCHW images (the
    /// serving path): one tall stacked im2col honouring the spec's
    /// stride/padding/dilation, one `(B·K, T, F)` square matmul, outputs
    /// scattered to `[image][filter][out_pixel]` order. The row
    /// partitioned threaded driver splits the `B·K` patch rows across
    /// workers, so batching widens the parallel section.
    pub fn apply_batch(
        &self,
        images_flat: &[T],
        batch: usize,
        in_h: usize,
        in_w: usize,
        cfg: &EngineConfig,
    ) -> Result<(Vec<T>, OpCounts), LinalgError> {
        self.apply_batch_with(images_flat, batch, in_h, in_w, |a| {
            matmul_square_prepared(a, &self.pb, cfg)
        })
    }

    /// The batch lowering pipeline (validate → stacked im2col → one
    /// matmul → scatter) with the matmul flavour supplied by the caller —
    /// the single definition of the serving layout, shared by the square
    /// path ([`Self::apply_batch`]) and the direct-multiplier shadow twin
    /// so the two can never disagree on it.
    pub fn apply_batch_with(
        &self,
        images_flat: &[T],
        batch: usize,
        in_h: usize,
        in_w: usize,
        matmul: impl FnOnce(&Matrix<T>) -> (Matrix<T>, OpCounts),
    ) -> Result<(Vec<T>, OpCounts), LinalgError> {
        let (out_h, out_w) = self.check_batch(images_flat, batch, in_h, in_w)?;
        let k_out = out_h * out_w;
        let a = im2col_nchw(images_flat, batch, in_h, in_w, &self.spec);
        let (c, ops) = matmul(&a);
        Ok((scatter_bank_output(&c, batch, k_out, self.filters()), ops))
    }

    /// [`Self::apply_batch`] with every intermediate drawn from an
    /// [`EngineWorkspace`]: the patch matrix, the GEMM output, the row
    /// corrections and the scattered output all reuse checked-out
    /// buffers, so a warmed steady state performs **zero** heap
    /// allocations per batch with `cfg.threads == 1` (the scoped threaded
    /// driver allocates per spawn — the threaded path trades the
    /// guarantee for parallelism). `out` is cleared and refilled with the
    /// same `[image][filter][out_pixel]` layout; values and ledger are
    /// identical to the allocating form.
    pub fn apply_batch_ws(
        &self,
        images_flat: &[T],
        batch: usize,
        in_h: usize,
        in_w: usize,
        cfg: &EngineConfig,
        ws: &mut EngineWorkspace<T>,
        out: &mut Vec<T>,
    ) -> Result<OpCounts, LinalgError> {
        let taps = self.taps();
        let filters = self.filters();
        self.apply_batch_ws_with(images_flat, batch, in_h, in_w, ws, out, |a, ws, c| {
            let ops = matmul_square_prepared_into(a, &self.pb, cfg, ws, c);
            debug_assert_eq!(ops, square_matmul_const_b_ledger(a.rows, taps, filters));
            ops
        })
    }

    /// [`Self::apply_batch_ws`] with the *direct multiplier* matmul — the
    /// workspace path of the shadow twin, so a sampled cross-check batch
    /// is as allocation-free as the square path it verifies. Identical
    /// lowering pipeline and layout (shared
    /// [`Self::apply_batch_ws_with`] core); only the matmul flavour — and
    /// therefore the ledger — differs.
    pub fn apply_batch_direct_ws(
        &self,
        images_flat: &[T],
        batch: usize,
        in_h: usize,
        in_w: usize,
        cfg: &EngineConfig,
        ws: &mut EngineWorkspace<T>,
        out: &mut Vec<T>,
    ) -> Result<OpCounts, LinalgError> {
        self.apply_batch_ws_with(images_flat, batch, in_h, in_w, ws, out, |a, _ws, c| {
            matmul_direct_blocked_into(a, self.pb.matrix(), cfg, c)
        })
    }

    /// The workspace batch pipeline (validate → stacked im2col into a
    /// checkout → one matmul into a checkout → scatter into `out`) with
    /// the matmul flavour supplied by the caller — the single definition
    /// of the zero-allocation serving layout, shared by the square path
    /// and the direct shadow twin exactly as [`Self::apply_batch_with`]
    /// is for the allocating forms.
    fn apply_batch_ws_with(
        &self,
        images_flat: &[T],
        batch: usize,
        in_h: usize,
        in_w: usize,
        ws: &mut EngineWorkspace<T>,
        out: &mut Vec<T>,
        matmul_into: impl FnOnce(&Matrix<T>, &mut EngineWorkspace<T>, &mut Vec<T>) -> OpCounts,
    ) -> Result<OpCounts, LinalgError> {
        let (out_h, out_w) = self.check_batch(images_flat, batch, in_h, in_w)?;
        let k_out = out_h * out_w;
        let taps = self.taps();
        let rows = batch * k_out;

        let mut patch = ws.checkout(rows * taps);
        im2col_nchw_into(&mut patch, images_flat, batch, in_h, in_w, &self.spec);
        let a = Matrix::from_vec(rows, taps, patch);

        let mut c = ws.checkout(rows * self.filters());
        let ops = matmul_into(&a, ws, &mut c);

        scatter_bank_output_into(&c, batch, k_out, self.filters(), out);
        ws.give_back(a.into_data());
        ws.give_back(c);
        Ok(ops)
    }

    /// The shared batch-contract check: validated output shape, non-empty
    /// batch, buffer length `batch · C·in_h·in_w`.
    fn check_batch(
        &self,
        images_flat: &[T],
        batch: usize,
        in_h: usize,
        in_w: usize,
    ) -> Result<(usize, usize), LinalgError> {
        let shape = self.output_shape(in_h, in_w)?;
        if batch == 0 {
            return Err(LinalgError::EmptyInput { what: "image batch" });
        }
        let img_len = self.spec.image_len(in_h, in_w);
        if images_flat.len() != batch * img_len {
            return Err(LinalgError::ShapeMismatch {
                what: "image batch buffer",
                expected: (batch, img_len),
                got: (1, images_flat.len()),
            });
        }
        Ok(shape)
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::conv::{conv2d_direct, conv2d_nchw_direct, conv2d_square};
    use super::*;
    use crate::testkit::{forall, Rng};

    fn tiny_cfg(threads: usize) -> EngineConfig {
        EngineConfig { block_k: 3, block_n: 5, threads }
    }

    #[test]
    fn blocked_conv_matches_direct_across_shapes() {
        forall(
            0xC01,
            40,
            |rng, size| {
                let kh = rng.usize_in(1, size.max(1).min(5));
                let kw = rng.usize_in(1, size.max(1).min(5));
                let h = kh + rng.usize_in(0, 9);
                let w = kw + rng.usize_in(0, 9);
                (
                    Matrix::random(rng, kh, kw, -200, 200),
                    Matrix::random(rng, h, w, -200, 200),
                )
            },
            |(ker, img)| {
                let want = conv2d_direct(ker, img).unwrap().0;
                for threads in [1usize, 4] {
                    let (got, _) = conv2d_square_blocked(ker, img, &tiny_cfg(threads)).unwrap();
                    if got != want {
                        return Err(format!(
                            "lowered conv diverged at k={}x{} x={}x{} threads={threads}",
                            ker.rows, ker.cols, img.rows, img.cols
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn lowered_ledger_equals_per_element_counting() {
        // re-derive the lowering's ledger the seed-tree way — one closure
        // call per scalar op of the (K, T, F) square matmul — and assert
        // the hoisted formula is identical, field by field
        fn lowered_ref(k: usize, t: usize, f: usize) -> OpCounts {
            let mut ops = OpCounts::ZERO;
            for _ in 0..k * t {
                ops.square(); // row corrections of the patch matrix
                ops.add();
            }
            for _ in 0..t * f {
                ops.square(); // column corrections of the bank
                ops.add();
            }
            for _out in 0..k * f {
                ops.add(); // correction seed
                for _tap in 0..t {
                    ops.square(); // (a + b)² window term
                    ops.add_n(2);
                }
                ops.shift(); // trailing exact ÷2
            }
            ops
        }
        let mut rng = Rng::new(0xC02);
        for (kh, kw, h, w) in [(1usize, 1usize, 1usize, 1usize), (3, 3, 8, 10), (2, 4, 7, 6)] {
            let ker = Matrix::random(&mut rng, kh, kw, -40, 40);
            let img = Matrix::random(&mut rng, h, w, -40, 40);
            let (_, ops) = conv2d_square_blocked(&ker, &img, &tiny_cfg(1)).unwrap();
            let k = (h - kh + 1) * (w - kw + 1);
            assert_eq!(ops, lowered_ref(k, kh * kw, 1), "{kh}x{kw} over {h}x{w}");
        }
    }

    #[test]
    fn bank_maps_match_per_filter_direct_conv() {
        let mut rng = Rng::new(0xC03);
        let filters: Vec<Matrix<i64>> = (0..5)
            .map(|_| Matrix::random(&mut rng, 3, 3, -80, 80))
            .collect();
        let img = Matrix::random(&mut rng, 9, 11, -80, 80);
        let (bank, prep_ops) = PreparedConvBank::new(&filters).unwrap();
        assert_eq!(prep_ops.squares, 9 * 5);
        assert_eq!(bank.filters(), 5);
        assert_eq!(bank.taps(), 9);
        assert_eq!(bank.in_channels(), 1);
        assert_eq!(*bank.spec(), ConvSpec::new(1, 5, 3, 3));

        let (maps, call_ops) = bank.apply(&img, &tiny_cfg(2)).unwrap();
        assert_eq!(maps.len(), 5);
        for (f, ker) in filters.iter().enumerate() {
            let (want, _) = conv2d_direct(ker, &img).unwrap();
            assert_eq!(maps[f], want, "filter {f}");
        }
        // per-call ledger: the bank corrections are amortised away
        assert_eq!(call_ops, square_matmul_const_b_ledger(7 * 9, 9, 5));
        // ...and prep + per-call equals the one-shot full ledger
        assert_eq!(
            call_ops + prep_ops,
            square_matmul_ledger(7 * 9, 9, 5),
            "§3 amortisation must be exact"
        );
    }

    #[test]
    fn bank_beats_naive_on_squares_at_cnn_scale() {
        // the lowering's algorithmic claim: at CNN scale (many filters,
        // one image) the shared im2col + bank corrections spend fewer
        // squares than F independent conv2d_square calls
        let mut rng = Rng::new(0xC04);
        let filters: Vec<Matrix<i64>> = (0..16)
            .map(|_| Matrix::random(&mut rng, 3, 3, -50, 50))
            .collect();
        let img = Matrix::random(&mut rng, 64, 64, -50, 50);
        let (bank, prep) = PreparedConvBank::new(&filters).unwrap();
        let (_, call) = bank.apply(&img, &EngineConfig::default()).unwrap();
        let naive: u64 = filters
            .iter()
            .map(|f| conv2d_square(f, &img).unwrap().1.squares)
            .sum();
        assert!(
            call.squares + prep.squares < naive,
            "lowered {} + prep {} vs naive {naive}",
            call.squares,
            prep.squares
        );
    }

    #[test]
    fn apply_batch_equals_per_image_apply() {
        let mut rng = Rng::new(0xC05);
        let filters: Vec<Matrix<i64>> = (0..3)
            .map(|_| Matrix::random(&mut rng, 2, 2, -30, 30))
            .collect();
        let (bank, _) = PreparedConvBank::new(&filters).unwrap();
        let (in_h, in_w) = (5usize, 6usize);
        let imgs: Vec<Matrix<i64>> = (0..4)
            .map(|_| Matrix::random(&mut rng, in_h, in_w, -30, 30))
            .collect();
        let flat: Vec<i64> = imgs.iter().flat_map(|m| m.data().to_vec()).collect();
        let (out, _) = bank
            .apply_batch(&flat, 4, in_h, in_w, &tiny_cfg(4))
            .unwrap();
        let k_out = 4 * 5;
        assert_eq!(out.len(), 4 * 3 * k_out);
        for (b, img) in imgs.iter().enumerate() {
            let (maps, _) = bank.apply(img, &tiny_cfg(1)).unwrap();
            for (f, map) in maps.iter().enumerate() {
                let got = &out[(b * 3 + f) * k_out..(b * 3 + f + 1) * k_out];
                assert_eq!(got, map.data(), "image {b} filter {f}");
            }
        }
    }

    #[test]
    fn nchw_bank_matches_direct_reference_across_geometries() {
        forall(
            0xC07,
            30,
            |rng, size| {
                let in_ch = rng.usize_in(1, 3);
                let filters_n = rng.usize_in(1, 4);
                let k = rng.usize_in(1, size.max(1).min(3));
                let spec = ConvSpec::new(in_ch, filters_n, k, k)
                    .with_stride(rng.usize_in(1, 3))
                    .with_padding(rng.usize_in(0, 2));
                let in_h = k + rng.usize_in(0, 7);
                let in_w = k + rng.usize_in(0, 7);
                let batch = rng.usize_in(1, 3);
                let images = rng.vec_i64(batch * spec.image_len(in_h, in_w), -60, 60);
                let filters = rng.vec_i64(spec.bank_len(), -60, 60);
                (spec, in_h, in_w, batch, images, filters)
            },
            |(spec, in_h, in_w, batch, images, filters)| {
                let (want, _) =
                    conv2d_nchw_direct(images, *batch, *in_h, *in_w, filters, spec).unwrap();
                let (bank, prep) = PreparedConvBank::new_nchw(filters, *spec).unwrap();
                if prep.squares != (spec.taps() * spec.out_channels) as u64 {
                    return Err("NCHW bank prep ledger wrong".into());
                }
                let k = *batch * spec.output_pixels(*in_h, *in_w).unwrap();
                let mut runs = Vec::new();
                for threads in [1usize, 4] {
                    let (got, ops) = bank
                        .apply_batch(images, *batch, *in_h, *in_w, &tiny_cfg(threads))
                        .unwrap();
                    if got != want {
                        return Err(format!(
                            "NCHW lowering diverged from direct reference at {spec:?} \
                             {in_h}x{in_w} batch {batch} threads {threads}"
                        ));
                    }
                    if ops != square_matmul_const_b_ledger(k, spec.taps(), spec.out_channels) {
                        return Err("NCHW lowering ledger diverged from its formula".into());
                    }
                    runs.push((got, ops));
                }
                if runs[0] != runs[1] {
                    return Err("threaded NCHW lowering not byte-identical".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn workspace_path_is_byte_identical_and_stops_allocating() {
        let mut rng = Rng::new(0xC08);
        let spec = ConvSpec::new(2, 4, 3, 3).with_stride(2).with_padding(1);
        let (in_h, in_w, batch) = (11usize, 9usize, 3usize);
        let filters = rng.vec_i64(spec.bank_len(), -40, 40);
        let (bank, _) = PreparedConvBank::new_nchw(&filters, spec).unwrap();

        let mut ws = EngineWorkspace::new();
        let mut out = Vec::new();
        for round in 0..4 {
            let images = rng.vec_i64(batch * spec.image_len(in_h, in_w), -40, 40);
            let (want, want_ops) = bank
                .apply_batch(&images, batch, in_h, in_w, &tiny_cfg(1))
                .unwrap();
            let ops = bank
                .apply_batch_ws(&images, batch, in_h, in_w, &tiny_cfg(1), &mut ws, &mut out)
                .unwrap();
            assert_eq!(out, want, "round {round}");
            assert_eq!(ops, want_ops, "round {round}");
        }
        // three checkouts per batch (patch, GEMM output, row corrections):
        // only the first round may touch the allocator
        assert_eq!(ws.checkouts(), 12);
        assert_eq!(ws.grows(), 3, "steady state must reuse retained buffers");
    }

    #[test]
    fn direct_workspace_path_matches_the_allocating_shadow_pipeline() {
        use super::super::blocked::matmul_direct_blocked;

        let mut rng = Rng::new(0xC09);
        let spec = ConvSpec::new(2, 3, 3, 3).with_stride(2).with_padding(1);
        let (in_h, in_w, batch) = (10usize, 9usize, 2usize);
        let filters = rng.vec_i64(spec.bank_len(), -40, 40);
        let (bank, _) = PreparedConvBank::new_nchw(&filters, spec).unwrap();

        let mut ws = EngineWorkspace::new();
        let mut out = Vec::new();
        for round in 0..3 {
            let images = rng.vec_i64(batch * spec.image_len(in_h, in_w), -40, 40);
            let (want, want_ops) = bank
                .apply_batch_with(&images, batch, in_h, in_w, |a| {
                    matmul_direct_blocked(a, bank.matrix(), &tiny_cfg(1))
                })
                .unwrap();
            let ops = bank
                .apply_batch_direct_ws(
                    &images, batch, in_h, in_w, &tiny_cfg(1), &mut ws, &mut out,
                )
                .unwrap();
            assert_eq!(out, want, "round {round}");
            assert_eq!(ops, want_ops, "round {round}");
            // the multiplier twin agrees with the square path on values
            let (sq, _) = bank
                .apply_batch(&images, batch, in_h, in_w, &tiny_cfg(1))
                .unwrap();
            assert_eq!(out, sq, "round {round}: twins disagree");
        }
        // two checkouts per direct batch (patch + GEMM output): only the
        // first round may touch the allocator
        assert_eq!(ws.checkouts(), 6);
        assert_eq!(ws.grows(), 2, "shadow steady state must reuse retained buffers");
    }

    #[test]
    fn threaded_bank_is_byte_identical() {
        let mut rng = Rng::new(0xC06);
        let filters: Vec<Matrix<i64>> = (0..4)
            .map(|_| Matrix::random(&mut rng, 3, 2, -99, 99))
            .collect();
        let img = Matrix::random(&mut rng, 17, 13, -99, 99);
        let (bank, _) = PreparedConvBank::new(&filters).unwrap();
        let (single, ops1) = bank.apply(&img, &tiny_cfg(1)).unwrap();
        let (multi, ops4) = bank.apply(&img, &tiny_cfg(4)).unwrap();
        assert_eq!(single, multi);
        assert_eq!(ops1, ops4, "ledger must not depend on the thread count");
    }

    #[test]
    fn lowering_shape_errors_are_typed() {
        let ker = Matrix::<i64>::zeros(4, 4);
        let img = Matrix::<i64>::zeros(3, 3);
        assert_eq!(
            conv2d_square_blocked(&ker, &img, &EngineConfig::default()).unwrap_err(),
            LinalgError::KernelDoesNotFit {
                kh: 4,
                kw: 4,
                in_h: 3,
                in_w: 3,
                stride: (1, 1),
                pad: (0, 0),
                dilation: (1, 1),
            }
        );
        assert_eq!(
            PreparedConvBank::<i64>::new(&[]).unwrap_err(),
            LinalgError::EmptyInput { what: "filter bank" }
        );
        let ragged = [Matrix::<i64>::zeros(3, 3), Matrix::<i64>::zeros(2, 3)];
        assert!(matches!(
            PreparedConvBank::new(&ragged).unwrap_err(),
            LinalgError::ShapeMismatch { .. }
        ));
        let (bank, _) = PreparedConvBank::new(&[Matrix::<i64>::zeros(3, 3)]).unwrap();
        assert!(bank.apply(&img, &EngineConfig::default()).is_ok());
        assert_eq!(
            bank.apply(&Matrix::zeros(2, 9), &EngineConfig::default())
                .unwrap_err(),
            LinalgError::KernelDoesNotFit {
                kh: 3,
                kw: 3,
                in_h: 2,
                in_w: 9,
                stride: (1, 1),
                pad: (0, 0),
                dilation: (1, 1),
            }
        );
        // batch buffer size must match the declared geometry
        assert!(matches!(
            bank.apply_batch(&[0i64; 10], 2, 3, 3, &EngineConfig::default())
                .unwrap_err(),
            LinalgError::ShapeMismatch { .. }
        ));
        assert_eq!(
            bank.apply_batch(&[], 0, 3, 3, &EngineConfig::default())
                .unwrap_err(),
            LinalgError::EmptyInput { what: "image batch" }
        );
    }

    #[test]
    fn nchw_spec_errors_are_typed() {
        // a misconfigured spec fails at construction with the full story
        let spec = ConvSpec::new(0, 4, 3, 3);
        assert_eq!(
            PreparedConvBank::<i64>::new_nchw(&[], spec).unwrap_err(),
            LinalgError::InvalidConvSpec { field: "in_channels" }
        );
        let spec = ConvSpec::new(2, 2, 3, 3).with_stride(2);
        // wrong bank buffer length
        assert!(matches!(
            PreparedConvBank::<i64>::new_nchw(&[0; 7], spec).unwrap_err(),
            LinalgError::ShapeMismatch { .. }
        ));
        let filters = vec![0i64; spec.bank_len()];
        let (bank, _) = PreparedConvBank::new_nchw(&filters, spec).unwrap();
        // stride/pad are reported when the kernel cannot be placed
        assert_eq!(
            bank.apply_batch(&[0i64; 2 * 2 * 2], 1, 2, 2, &EngineConfig::default())
                .unwrap_err(),
            LinalgError::KernelDoesNotFit {
                kh: 3,
                kw: 3,
                in_h: 2,
                in_w: 2,
                stride: (2, 2),
                pad: (0, 0),
                dilation: (1, 1),
            }
        );
        // a multi-channel bank refuses the single-plane apply()
        assert!(matches!(
            bank.apply(&Matrix::<i64>::zeros(8, 8), &EngineConfig::default())
                .unwrap_err(),
            LinalgError::ShapeMismatch { .. }
        ));
        // wrong batch buffer length for the channel count
        assert!(matches!(
            bank.apply_batch(&[0i64; 64], 1, 8, 8, &EngineConfig::default())
                .unwrap_err(),
            LinalgError::ShapeMismatch { .. }
        ));
    }
}
