//! Row-partitioned parallel driver on `std::thread::scope`.
//!
//! The output matrix's rows are split into contiguous chunks — one scoped
//! worker per chunk. Chunks are disjoint `&mut` slices carved with
//! `chunks_mut`, so there is no locking and no unsafe; the borrow checker
//! proves the partition. Scoped threads mean the borrowed A/B/corrections
//! need no `Arc`, keeping the driver dependency-free.

/// Worker count the machine supports (≥ 1 always).
pub fn max_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(i0, i1, chunk)` over contiguous row partitions of `data`
/// (row-major, `rows × cols`), one scoped thread per partition.
///
/// `f` sees the absolute row range `[i0, i1)` and that range's storage.
/// With `threads == 1` (or a single row) it runs inline on the caller's
/// thread — no spawn cost on the small-shape path.
pub fn for_row_chunks<T, F>(data: &mut [T], rows: usize, cols: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    // a real assert, not a debug_assert: in a release build a bad shape
    // would otherwise silently mis-partition rows across threads (each
    // chunk's row range is derived from `cols`), corrupting the output
    // instead of failing fast
    assert_eq!(
        data.len(),
        rows * cols,
        "for_row_chunks: data.len() must equal rows*cols ({rows}x{cols})"
    );
    if rows == 0 || cols == 0 {
        return;
    }
    let threads = threads.max(1).min(rows);
    if threads == 1 {
        f(0, rows, data);
        return;
    }
    let rows_per = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ci, chunk) in data.chunks_mut(rows_per * cols).enumerate() {
            let i0 = ci * rows_per;
            let i1 = i0 + chunk.len() / cols;
            let f = &f;
            scope.spawn(move || f(i0, i1, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_cover_exactly_once() {
        let (rows, cols) = (13usize, 7usize);
        let mut data = vec![0u64; rows * cols];
        for threads in [1, 2, 3, 5, 13, 64] {
            data.iter_mut().for_each(|v| *v = 0);
            for_row_chunks(&mut data, rows, cols, threads, |i0, i1, chunk| {
                assert_eq!(chunk.len(), (i1 - i0) * cols);
                for (r, row) in chunk.chunks_mut(cols).enumerate() {
                    for v in row {
                        *v += (i0 + r + 1) as u64; // row id, applied once
                    }
                }
            });
            for (idx, &v) in data.iter().enumerate() {
                assert_eq!(v, (idx / cols + 1) as u64, "threads={threads} idx={idx}");
            }
        }
    }

    #[test]
    fn empty_inputs_are_noops() {
        let mut empty: Vec<i64> = Vec::new();
        for_row_chunks(&mut empty, 0, 4, 8, |_, _, _| panic!("must not run"));
        for_row_chunks(&mut empty, 4, 0, 8, |_, _, _| panic!("must not run"));
    }

    #[test]
    fn max_threads_is_positive() {
        assert!(max_threads() >= 1);
    }

    #[test]
    #[should_panic(expected = "for_row_chunks: data.len() must equal rows*cols")]
    fn mismatched_shape_panics_in_every_build() {
        // 11 values cannot be 3 rows of 4 — must fail fast, not
        // mis-partition (this is a plain assert!, so it fires in release
        // builds too)
        let mut data = vec![0i64; 11];
        for_row_chunks(&mut data, 3, 4, 2, |_, _, _| {});
    }
}
