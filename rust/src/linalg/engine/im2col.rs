//! im2col patch extraction: the data-movement half of the conv2d lowering.
//!
//! A valid-mode `kh×kw` correlation over an `in_h×in_w` image becomes one
//! matmul once the image is unrolled into its *patch matrix* `A`:
//! row `oh·out_w + ow` of `A` is the flattened (row-major) `kh×kw` window
//! whose top-left corner sits at `(oh, ow)`, so
//!
//! ```text
//! A: (out_h·out_w) × (kh·kw)       — one row per output pixel
//! B: (kh·kw) × filters             — one column per flattened kernel
//! C = A·B: (out_h·out_w) × filters — column f is filter f's output map
//! ```
//!
//! and `C = A·B` runs on the cache-blocked, threaded square-matmul core,
//! with the filter bank playing the paper's §3 *constant B* role: its
//! column corrections are computed once per bank
//! ([`PreparedConvBank`](super::conv::PreparedConvBank)) and amortised
//! across every image and every filter.
//!
//! Extraction is pure data movement — zero arithmetic operations — so it
//! never appears in an [`OpCounts`](crate::linalg::OpCounts) ledger. Each
//! patch row is filled by `kh` contiguous `copy_from_slice` runs of `kw`
//! samples, the only layout the cost of which the lowering pays for its
//! locality win.
//!
//! Shape *policy* is the callers' job: the fallible entry points in
//! [`conv`](super::conv) turn bad geometry into a typed
//! [`LinalgError`](crate::linalg::LinalgError) via
//! [`conv2d_output_shape`](crate::linalg::conv::conv2d_output_shape)
//! before calling down here. These helpers are still exported, so they
//! guard their preconditions with real `assert!`s — in a release build a
//! wrong dimension must fail fast, not silently scatter values into the
//! wrong image's output block (the same promotion PR 2 made for
//! `for_row_chunks`).

use super::super::matrix::Matrix;
use super::spec::ConvSpec;
use super::SquareScalar;

/// Unroll one image into its `(out_h·out_w) × (kh·kw)` patch matrix.
///
/// Caller must have validated `kh <= x.rows && kw <= x.cols` and non-empty
/// operands (see module docs).
pub fn im2col<T: SquareScalar>(x: &Matrix<T>, kh: usize, kw: usize) -> Matrix<T> {
    assert!(
        kh >= 1 && kw >= 1 && x.rows >= kh && x.cols >= kw,
        "im2col: {kh}x{kw} kernel must fit a {}x{} image",
        x.rows,
        x.cols
    );
    let out_h = x.rows - kh + 1;
    let out_w = x.cols - kw + 1;
    let taps = kh * kw;
    let mut a = Matrix::zeros(out_h * out_w, taps);
    fill_patches(a.data_mut(), x.data(), x.cols, kh, kw, out_h, out_w);
    a
}

/// Unroll a batch of row-major flattened images (each `in_h·in_w` values,
/// concatenated) into one tall stacked patch matrix of
/// `(batch·out_h·out_w) × (kh·kw)`: image `i`'s patches occupy the row
/// block starting at `i·out_h·out_w`. One matmul against the bank then
/// serves the whole batch — the serving path's layout.
pub fn im2col_stacked<T: SquareScalar>(
    images_flat: &[T],
    batch: usize,
    in_h: usize,
    in_w: usize,
    kh: usize,
    kw: usize,
) -> Matrix<T> {
    assert!(
        kh >= 1 && kw >= 1 && in_h >= kh && in_w >= kw,
        "im2col_stacked: {kh}x{kw} kernel must fit a {in_h}x{in_w} image"
    );
    assert_eq!(
        images_flat.len(),
        batch * in_h * in_w,
        "im2col_stacked: buffer is not {batch} images of {in_h}x{in_w}"
    );
    let out_h = in_h - kh + 1;
    let out_w = in_w - kw + 1;
    let k_out = out_h * out_w;
    let taps = kh * kw;
    let mut a = Matrix::zeros(batch * k_out, taps);
    for b in 0..batch {
        let img = &images_flat[b * in_h * in_w..(b + 1) * in_h * in_w];
        let block = &mut a.data_mut()[b * k_out * taps..(b + 1) * k_out * taps];
        fill_patches(block, img, in_w, kh, kw, out_h, out_w);
    }
    a
}

/// Fill `rows` (the row-major storage of `out_h·out_w` patch rows of
/// `kh·kw` taps each) straight from a flat row-major image of width
/// `in_w`: contiguous `kw`-sample runs, one per kernel row per patch —
/// no intermediate image copy on the serving path.
fn fill_patches<T: SquareScalar>(
    rows: &mut [T],
    img: &[T],
    in_w: usize,
    kh: usize,
    kw: usize,
    out_h: usize,
    out_w: usize,
) {
    let taps = kh * kw;
    debug_assert_eq!(rows.len(), out_h * out_w * taps);
    for oh in 0..out_h {
        for i in 0..kh {
            let x_row = &img[(oh + i) * in_w..(oh + i + 1) * in_w];
            for ow in 0..out_w {
                let base = (oh * out_w + ow) * taps + i * kw;
                rows[base..base + kw].copy_from_slice(&x_row[ow..ow + kw]);
            }
        }
    }
}

/// Fill the stacked NCHW patch matrix for `spec` into `rows`: the
/// row-major storage of `(batch·out_h·out_w)` patch rows of
/// `spec.taps() = C·kh·kw` taps each, channel-major within a row
/// (`[c][i][j]` — the same order a flattened NCHW filter uses, so the
/// bank columns line up). Stride, zero-padding and dilation are honoured:
/// taps that fall in the padding are written as `T::default()`. Pure data
/// movement into a caller-provided (typically workspace-checked-out)
/// buffer — zero allocations. Geometry must have been validated by the
/// caller; like the other extraction helpers this guards with real
/// `assert!`s.
pub fn im2col_nchw_into<T: SquareScalar>(
    rows: &mut [T],
    images_flat: &[T],
    batch: usize,
    in_h: usize,
    in_w: usize,
    spec: &ConvSpec,
) {
    let (out_h, out_w) = spec
        .output_shape(in_h, in_w)
        .expect("im2col_nchw_into: invalid conv geometry (callers validate)");
    let taps = spec.taps();
    let k_out = out_h * out_w;
    let plane = in_h * in_w;
    assert_eq!(
        images_flat.len(),
        batch * spec.in_channels * plane,
        "im2col_nchw_into: buffer is not {batch} NCHW images of {}x{in_h}x{in_w}",
        spec.in_channels
    );
    assert_eq!(
        rows.len(),
        batch * k_out * taps,
        "im2col_nchw_into: patch buffer must hold {batch}*{k_out} rows of {taps} taps"
    );
    let khw = spec.kernel_h * spec.kernel_w;
    for b in 0..batch {
        let img = &images_flat[b * spec.in_channels * plane..][..spec.in_channels * plane];
        let block = &mut rows[b * k_out * taps..][..k_out * taps];
        for oh in 0..out_h {
            for ow in 0..out_w {
                let patch = &mut block[(oh * out_w + ow) * taps..][..taps];
                for c in 0..spec.in_channels {
                    let chan = &img[c * plane..][..plane];
                    for i in 0..spec.kernel_h {
                        let dst = &mut patch[c * khw + i * spec.kernel_w..][..spec.kernel_w];
                        let ih = oh * spec.stride_h + i * spec.dilation_h;
                        if ih < spec.pad_h || ih - spec.pad_h >= in_h {
                            dst.fill(T::default()); // whole kernel row in padding
                            continue;
                        }
                        let x_row = &chan[(ih - spec.pad_h) * in_w..][..in_w];
                        for (j, v) in dst.iter_mut().enumerate() {
                            let iw = ow * spec.stride_w + j * spec.dilation_w;
                            *v = if iw < spec.pad_w || iw - spec.pad_w >= in_w {
                                T::default()
                            } else {
                                x_row[iw - spec.pad_w]
                            };
                        }
                    }
                }
            }
        }
    }
}

/// Allocating wrapper over [`im2col_nchw_into`]: unroll a batch of NCHW
/// images into one tall `(batch·out_h·out_w) × (C·kh·kw)` patch matrix.
/// The one-shot path; the serving path reuses a workspace buffer instead.
pub fn im2col_nchw<T: SquareScalar>(
    images_flat: &[T],
    batch: usize,
    in_h: usize,
    in_w: usize,
    spec: &ConvSpec,
) -> Matrix<T> {
    let (out_h, out_w) = spec
        .output_shape(in_h, in_w)
        .expect("im2col_nchw: invalid conv geometry (callers validate)");
    let mut a = Matrix::zeros(batch * out_h * out_w, spec.taps());
    im2col_nchw_into(a.data_mut(), images_flat, batch, in_h, in_w, spec);
    a
}

/// Flatten an NCHW filter bank buffer (`[filter][channel][kh][kw]` order,
/// `spec.bank_len()` values) into the `(C·kh·kw) × F` weight matrix `B`:
/// column `f` is filter `f`'s taps in the same channel-major order the
/// patch rows use. Caller validates the length; asserted here too.
pub fn nchw_bank_matrix<T: SquareScalar>(filters_flat: &[T], spec: &ConvSpec) -> Matrix<T> {
    let taps = spec.taps();
    assert_eq!(
        filters_flat.len(),
        spec.out_channels * taps,
        "nchw_bank_matrix: bank buffer must hold {} filters of {taps} taps",
        spec.out_channels
    );
    Matrix::from_fn(taps, spec.out_channels, |t, f| filters_flat[f * taps + t])
}

/// Flatten a bank of same-shaped kernels into the `(kh·kw) × filters`
/// weight matrix `B`: column `f` is kernel `f` in row-major order. Caller
/// validates the bank (non-empty, uniform non-empty shapes).
pub fn bank_matrix<T: SquareScalar>(filters: &[Matrix<T>]) -> Matrix<T> {
    assert!(!filters.is_empty(), "bank_matrix: empty filter bank");
    let (kh, kw) = (filters[0].rows, filters[0].cols);
    assert!(
        filters.iter().all(|f| f.rows == kh && f.cols == kw),
        "bank_matrix: filters must share one {kh}x{kw} shape"
    );
    Matrix::from_fn(kh * kw, filters.len(), |t, f| filters[f].data()[t])
}

/// Re-scatter the lowered output `C` (`(batch·k_out) × filters`) into the
/// serving layout: per image, per filter, the flattened `out_h·out_w` map
/// — i.e. `out[(b·filters + f)·k_out + pix] = C[b·k_out + pix, f]`.
/// Pure data movement, like the extraction.
pub fn scatter_bank_output<T: SquareScalar>(
    c: &Matrix<T>,
    batch: usize,
    k_out: usize,
    filters: usize,
) -> Vec<T> {
    assert_eq!(
        c.rows,
        batch * k_out,
        "scatter_bank_output: C rows must be batch*k_out"
    );
    assert_eq!(c.cols, filters, "scatter_bank_output: C cols must be the filter count");
    let mut out = Vec::new();
    scatter_bank_output_into(c.data(), batch, k_out, filters, &mut out);
    out
}

/// [`scatter_bank_output`] into a reused buffer: `c_rows` is the
/// row-major storage of the lowered `(batch·k_out) × filters` output and
/// `out` is cleared + resized to `batch·filters·k_out` — zero allocations
/// once warm. The workspace half of the serving layout.
pub fn scatter_bank_output_into<T: SquareScalar>(
    c_rows: &[T],
    batch: usize,
    k_out: usize,
    filters: usize,
    out: &mut Vec<T>,
) {
    assert_eq!(
        c_rows.len(),
        batch * k_out * filters,
        "scatter_bank_output_into: C must be (batch*k_out) x filters"
    );
    out.clear();
    out.resize(batch * filters * k_out, T::default());
    for b in 0..batch {
        for pix in 0..k_out {
            let c_row = &c_rows[(b * k_out + pix) * filters..][..filters];
            for (f, &v) in c_row.iter().enumerate() {
                out[(b * filters + f) * k_out + pix] = v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    #[test]
    fn patches_match_manual_windows() {
        let mut rng = Rng::new(0x12C);
        let x = Matrix::random(&mut rng, 5, 7, -50, 50);
        let (kh, kw) = (2usize, 3usize);
        let a = im2col(&x, kh, kw);
        let (out_h, out_w) = (4usize, 5usize);
        assert_eq!((a.rows, a.cols), (out_h * out_w, kh * kw));
        for oh in 0..out_h {
            for ow in 0..out_w {
                let row = a.row(oh * out_w + ow);
                for i in 0..kh {
                    for j in 0..kw {
                        assert_eq!(row[i * kw + j], x.get(oh + i, ow + j));
                    }
                }
            }
        }
    }

    #[test]
    fn one_by_one_kernel_is_the_flat_image() {
        let mut rng = Rng::new(0x12D);
        let x = Matrix::random(&mut rng, 3, 4, -9, 9);
        let a = im2col(&x, 1, 1);
        assert_eq!((a.rows, a.cols), (12, 1));
        assert_eq!(a.data(), x.data());
    }

    #[test]
    fn stacked_batch_blocks_equal_per_image_extraction() {
        let mut rng = Rng::new(0x12E);
        let (in_h, in_w, kh, kw) = (4usize, 5usize, 3usize, 2usize);
        let imgs: Vec<Matrix<i64>> = (0..3)
            .map(|_| Matrix::random(&mut rng, in_h, in_w, -99, 99))
            .collect();
        let flat: Vec<i64> = imgs.iter().flat_map(|m| m.data().to_vec()).collect();
        let stacked = im2col_stacked(&flat, 3, in_h, in_w, kh, kw);
        let k_out = (in_h - kh + 1) * (in_w - kw + 1);
        assert_eq!(stacked.rows, 3 * k_out);
        for (b, img) in imgs.iter().enumerate() {
            let single = im2col(img, kh, kw);
            for pix in 0..k_out {
                assert_eq!(stacked.row(b * k_out + pix), single.row(pix), "image {b}");
            }
        }
    }

    #[test]
    fn nchw_single_channel_defaults_equal_the_legacy_extraction() {
        let mut rng = Rng::new(0x130);
        let (in_h, in_w, kh, kw, batch) = (5usize, 6usize, 3usize, 2usize, 3usize);
        let flat = rng.vec_i64(batch * in_h * in_w, -99, 99);
        let legacy = im2col_stacked(&flat, batch, in_h, in_w, kh, kw);
        let spec = ConvSpec::new(1, 1, kh, kw);
        let nchw = im2col_nchw(&flat, batch, in_h, in_w, &spec);
        assert_eq!(nchw, legacy, "C=1 stride-1 pad-0 NCHW must be the PR 3 layout");
    }

    #[test]
    fn nchw_strided_padded_patches_match_manual_windows() {
        let mut rng = Rng::new(0x131);
        let spec = ConvSpec {
            dilation_h: 2,
            ..ConvSpec::new(2, 1, 2, 3).with_stride(2).with_padding(1)
        };
        let (in_h, in_w, batch) = (6usize, 7usize, 2usize);
        let (out_h, out_w) = spec.output_shape(in_h, in_w).unwrap();
        let flat = rng.vec_i64(batch * spec.image_len(in_h, in_w), -50, 50);
        let a = im2col_nchw(&flat, batch, in_h, in_w, &spec);
        assert_eq!((a.rows, a.cols), (batch * out_h * out_w, spec.taps()));
        let plane = in_h * in_w;
        for b in 0..batch {
            for oh in 0..out_h {
                for ow in 0..out_w {
                    let row = a.row((b * out_h + oh) * out_w + ow);
                    for c in 0..spec.in_channels {
                        for i in 0..spec.kernel_h {
                            for j in 0..spec.kernel_w {
                                let ih = (oh * spec.stride_h + i * spec.dilation_h) as i64
                                    - spec.pad_h as i64;
                                let iw = (ow * spec.stride_w + j * spec.dilation_w) as i64
                                    - spec.pad_w as i64;
                                let want = if ih < 0
                                    || iw < 0
                                    || ih >= in_h as i64
                                    || iw >= in_w as i64
                                {
                                    0
                                } else {
                                    flat[(b * spec.in_channels + c) * plane
                                        + ih as usize * in_w
                                        + iw as usize]
                                };
                                let tap = (c * spec.kernel_h + i) * spec.kernel_w + j;
                                assert_eq!(
                                    row[tap], want,
                                    "b={b} oh={oh} ow={ow} c={c} i={i} j={j}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn nchw_into_reuses_a_dirty_buffer() {
        // workspace checkouts have unspecified contents, so the fill must
        // fully define the output: every element written or explicitly
        // zeroed — never inherited from the previous batch
        let mut rng = Rng::new(0x132);
        let spec = ConvSpec::new(2, 1, 3, 3).with_padding(2);
        let (in_h, in_w) = (4usize, 4usize);
        let flat = rng.vec_i64(spec.image_len(in_h, in_w), -30, 30);
        let want = im2col_nchw(&flat, 1, in_h, in_w, &spec);
        let mut dirty = vec![i64::MIN; want.rows * want.cols];
        im2col_nchw_into(&mut dirty, &flat, 1, in_h, in_w, &spec);
        assert_eq!(dirty, want.data());
    }

    #[test]
    fn nchw_bank_matrix_columns_are_channel_major_filters() {
        let mut rng = Rng::new(0x133);
        let spec = ConvSpec::new(3, 4, 2, 2);
        let flat = rng.vec_i64(spec.bank_len(), -20, 20);
        let b = nchw_bank_matrix(&flat, &spec);
        assert_eq!((b.rows, b.cols), (12, 4));
        for f in 0..4 {
            for t in 0..12 {
                assert_eq!(b.get(t, f), flat[f * 12 + t]);
            }
        }
    }

    #[test]
    fn scatter_into_matches_allocating_scatter() {
        let (batch, k_out, filters) = (2usize, 4usize, 3usize);
        let c = Matrix::from_fn(batch * k_out, filters, |r, f| (r * 100 + f) as i64);
        let want = scatter_bank_output(&c, batch, k_out, filters);
        let mut out = vec![0i64; 1]; // wrong size on purpose: must be resized
        scatter_bank_output_into(c.data(), batch, k_out, filters, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    fn bank_matrix_columns_are_flattened_kernels() {
        let mut rng = Rng::new(0x12F);
        let filters: Vec<Matrix<i64>> = (0..4)
            .map(|_| Matrix::random(&mut rng, 2, 3, -20, 20))
            .collect();
        let b = bank_matrix(&filters);
        assert_eq!((b.rows, b.cols), (6, 4));
        for (f, ker) in filters.iter().enumerate() {
            for t in 0..6 {
                assert_eq!(b.get(t, f), ker.data()[t]);
            }
        }
    }

    #[test]
    fn scatter_round_trips_the_lowered_layout() {
        // C[b*k_out + pix, f] must land at out[(b*F + f)*k_out + pix]
        let (batch, k_out, filters) = (2usize, 3usize, 2usize);
        let c = Matrix::from_fn(batch * k_out, filters, |r, f| (r * 10 + f) as i64);
        let out = scatter_bank_output(&c, batch, k_out, filters);
        for b in 0..batch {
            for f in 0..filters {
                for pix in 0..k_out {
                    assert_eq!(
                        out[(b * filters + f) * k_out + pix],
                        c.get(b * k_out + pix, f)
                    );
                }
            }
        }
    }
}
