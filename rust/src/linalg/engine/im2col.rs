//! im2col patch extraction: the data-movement half of the conv2d lowering.
//!
//! A valid-mode `kh×kw` correlation over an `in_h×in_w` image becomes one
//! matmul once the image is unrolled into its *patch matrix* `A`:
//! row `oh·out_w + ow` of `A` is the flattened (row-major) `kh×kw` window
//! whose top-left corner sits at `(oh, ow)`, so
//!
//! ```text
//! A: (out_h·out_w) × (kh·kw)       — one row per output pixel
//! B: (kh·kw) × filters             — one column per flattened kernel
//! C = A·B: (out_h·out_w) × filters — column f is filter f's output map
//! ```
//!
//! and `C = A·B` runs on the cache-blocked, threaded square-matmul core,
//! with the filter bank playing the paper's §3 *constant B* role: its
//! column corrections are computed once per bank
//! ([`PreparedConvBank`](super::conv::PreparedConvBank)) and amortised
//! across every image and every filter.
//!
//! Extraction is pure data movement — zero arithmetic operations — so it
//! never appears in an [`OpCounts`](crate::linalg::OpCounts) ledger. Each
//! patch row is filled by `kh` contiguous `copy_from_slice` runs of `kw`
//! samples, the only layout the cost of which the lowering pays for its
//! locality win.
//!
//! Shape *policy* is the callers' job: the fallible entry points in
//! [`conv`](super::conv) turn bad geometry into a typed
//! [`LinalgError`](crate::linalg::LinalgError) via
//! [`conv2d_output_shape`](crate::linalg::conv::conv2d_output_shape)
//! before calling down here. These helpers are still exported, so they
//! guard their preconditions with real `assert!`s — in a release build a
//! wrong dimension must fail fast, not silently scatter values into the
//! wrong image's output block (the same promotion PR 2 made for
//! `for_row_chunks`).

use super::super::matrix::Matrix;
use super::SquareScalar;

/// Unroll one image into its `(out_h·out_w) × (kh·kw)` patch matrix.
///
/// Caller must have validated `kh <= x.rows && kw <= x.cols` and non-empty
/// operands (see module docs).
pub fn im2col<T: SquareScalar>(x: &Matrix<T>, kh: usize, kw: usize) -> Matrix<T> {
    assert!(
        kh >= 1 && kw >= 1 && x.rows >= kh && x.cols >= kw,
        "im2col: {kh}x{kw} kernel must fit a {}x{} image",
        x.rows,
        x.cols
    );
    let out_h = x.rows - kh + 1;
    let out_w = x.cols - kw + 1;
    let taps = kh * kw;
    let mut a = Matrix::zeros(out_h * out_w, taps);
    fill_patches(a.data_mut(), x.data(), x.cols, kh, kw, out_h, out_w);
    a
}

/// Unroll a batch of row-major flattened images (each `in_h·in_w` values,
/// concatenated) into one tall stacked patch matrix of
/// `(batch·out_h·out_w) × (kh·kw)`: image `i`'s patches occupy the row
/// block starting at `i·out_h·out_w`. One matmul against the bank then
/// serves the whole batch — the serving path's layout.
pub fn im2col_stacked<T: SquareScalar>(
    images_flat: &[T],
    batch: usize,
    in_h: usize,
    in_w: usize,
    kh: usize,
    kw: usize,
) -> Matrix<T> {
    assert!(
        kh >= 1 && kw >= 1 && in_h >= kh && in_w >= kw,
        "im2col_stacked: {kh}x{kw} kernel must fit a {in_h}x{in_w} image"
    );
    assert_eq!(
        images_flat.len(),
        batch * in_h * in_w,
        "im2col_stacked: buffer is not {batch} images of {in_h}x{in_w}"
    );
    let out_h = in_h - kh + 1;
    let out_w = in_w - kw + 1;
    let k_out = out_h * out_w;
    let taps = kh * kw;
    let mut a = Matrix::zeros(batch * k_out, taps);
    for b in 0..batch {
        let img = &images_flat[b * in_h * in_w..(b + 1) * in_h * in_w];
        let block = &mut a.data_mut()[b * k_out * taps..(b + 1) * k_out * taps];
        fill_patches(block, img, in_w, kh, kw, out_h, out_w);
    }
    a
}

/// Fill `rows` (the row-major storage of `out_h·out_w` patch rows of
/// `kh·kw` taps each) straight from a flat row-major image of width
/// `in_w`: contiguous `kw`-sample runs, one per kernel row per patch —
/// no intermediate image copy on the serving path.
fn fill_patches<T: SquareScalar>(
    rows: &mut [T],
    img: &[T],
    in_w: usize,
    kh: usize,
    kw: usize,
    out_h: usize,
    out_w: usize,
) {
    let taps = kh * kw;
    debug_assert_eq!(rows.len(), out_h * out_w * taps);
    for oh in 0..out_h {
        for i in 0..kh {
            let x_row = &img[(oh + i) * in_w..(oh + i + 1) * in_w];
            for ow in 0..out_w {
                let base = (oh * out_w + ow) * taps + i * kw;
                rows[base..base + kw].copy_from_slice(&x_row[ow..ow + kw]);
            }
        }
    }
}

/// Flatten a bank of same-shaped kernels into the `(kh·kw) × filters`
/// weight matrix `B`: column `f` is kernel `f` in row-major order. Caller
/// validates the bank (non-empty, uniform non-empty shapes).
pub fn bank_matrix<T: SquareScalar>(filters: &[Matrix<T>]) -> Matrix<T> {
    assert!(!filters.is_empty(), "bank_matrix: empty filter bank");
    let (kh, kw) = (filters[0].rows, filters[0].cols);
    assert!(
        filters.iter().all(|f| f.rows == kh && f.cols == kw),
        "bank_matrix: filters must share one {kh}x{kw} shape"
    );
    Matrix::from_fn(kh * kw, filters.len(), |t, f| filters[f].data()[t])
}

/// Re-scatter the lowered output `C` (`(batch·k_out) × filters`) into the
/// serving layout: per image, per filter, the flattened `out_h·out_w` map
/// — i.e. `out[(b·filters + f)·k_out + pix] = C[b·k_out + pix, f]`.
/// Pure data movement, like the extraction.
pub fn scatter_bank_output<T: SquareScalar>(
    c: &Matrix<T>,
    batch: usize,
    k_out: usize,
    filters: usize,
) -> Vec<T> {
    assert_eq!(
        c.rows,
        batch * k_out,
        "scatter_bank_output: C rows must be batch*k_out"
    );
    assert_eq!(c.cols, filters, "scatter_bank_output: C cols must be the filter count");
    let mut out = vec![T::default(); batch * filters * k_out];
    for b in 0..batch {
        for pix in 0..k_out {
            let c_row = c.row(b * k_out + pix);
            for (f, &v) in c_row.iter().enumerate() {
                out[(b * filters + f) * k_out + pix] = v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    #[test]
    fn patches_match_manual_windows() {
        let mut rng = Rng::new(0x12C);
        let x = Matrix::random(&mut rng, 5, 7, -50, 50);
        let (kh, kw) = (2usize, 3usize);
        let a = im2col(&x, kh, kw);
        let (out_h, out_w) = (4usize, 5usize);
        assert_eq!((a.rows, a.cols), (out_h * out_w, kh * kw));
        for oh in 0..out_h {
            for ow in 0..out_w {
                let row = a.row(oh * out_w + ow);
                for i in 0..kh {
                    for j in 0..kw {
                        assert_eq!(row[i * kw + j], x.get(oh + i, ow + j));
                    }
                }
            }
        }
    }

    #[test]
    fn one_by_one_kernel_is_the_flat_image() {
        let mut rng = Rng::new(0x12D);
        let x = Matrix::random(&mut rng, 3, 4, -9, 9);
        let a = im2col(&x, 1, 1);
        assert_eq!((a.rows, a.cols), (12, 1));
        assert_eq!(a.data(), x.data());
    }

    #[test]
    fn stacked_batch_blocks_equal_per_image_extraction() {
        let mut rng = Rng::new(0x12E);
        let (in_h, in_w, kh, kw) = (4usize, 5usize, 3usize, 2usize);
        let imgs: Vec<Matrix<i64>> = (0..3)
            .map(|_| Matrix::random(&mut rng, in_h, in_w, -99, 99))
            .collect();
        let flat: Vec<i64> = imgs.iter().flat_map(|m| m.data().to_vec()).collect();
        let stacked = im2col_stacked(&flat, 3, in_h, in_w, kh, kw);
        let k_out = (in_h - kh + 1) * (in_w - kw + 1);
        assert_eq!(stacked.rows, 3 * k_out);
        for (b, img) in imgs.iter().enumerate() {
            let single = im2col(img, kh, kw);
            for pix in 0..k_out {
                assert_eq!(stacked.row(b * k_out + pix), single.row(pix), "image {b}");
            }
        }
    }

    #[test]
    fn bank_matrix_columns_are_flattened_kernels() {
        let mut rng = Rng::new(0x12F);
        let filters: Vec<Matrix<i64>> = (0..4)
            .map(|_| Matrix::random(&mut rng, 2, 3, -20, 20))
            .collect();
        let b = bank_matrix(&filters);
        assert_eq!((b.rows, b.cols), (6, 4));
        for (f, ker) in filters.iter().enumerate() {
            for t in 0..6 {
                assert_eq!(b.get(t, f), ker.data()[t]);
            }
        }
    }

    #[test]
    fn scatter_round_trips_the_lowered_layout() {
        // C[b*k_out + pix, f] must land at out[(b*F + f)*k_out + pix]
        let (batch, k_out, filters) = (2usize, 3usize, 2usize);
        let c = Matrix::from_fn(batch * k_out, filters, |r, f| (r * 10 + f) as i64);
        let out = scatter_bank_output(&c, batch, k_out, filters);
        for b in 0..batch {
            for f in 0..filters {
                for pix in 0..k_out {
                    assert_eq!(
                        out[(b * filters + f) * k_out + pix],
                        c.get(b * k_out + pix, f)
                    );
                }
            }
        }
    }
}
