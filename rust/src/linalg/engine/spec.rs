//! `ConvSpec` — the shape descriptor of the generalized convolution
//! subsystem.
//!
//! PR 3's lowering handled exactly one convolution shape: single-channel,
//! stride-1, zero-padding valid correlation. Real CNN serving traffic is
//! NCHW — `in_channels` stacked planes per image, `out_channels` filters
//! that each span *every* input channel — with stride and padding (and,
//! on dilated architectures, dilation). `ConvSpec` names that whole
//! family once, validates it once, and is the single source of the
//! output-size arithmetic for the reference kernel
//! ([`conv2d_nchw_direct`](crate::linalg::conv::conv2d_nchw_direct)), the
//! im2col lowering ([`im2col_nchw`](super::im2col::im2col_nchw)), the
//! prepared bank ([`PreparedConvBank`](super::conv::PreparedConvBank))
//! and the serving executors — so none of them can disagree on geometry.
//!
//! A misconfigured spec fails with a typed [`LinalgError`] carrying the
//! full stride/padding/dilation picture
//! ([`LinalgError::KernelDoesNotFit`] /
//! [`LinalgError::InvalidConvSpec`]), never a panic or a silent `usize`
//! underflow in the output-size subtraction.

use super::super::LinalgError;

/// Shape descriptor for an NCHW 2-D convolution: channel counts, kernel
/// size, stride, padding and dilation. `new` gives the PR 3 defaults
/// (stride 1, no padding, no dilation); the `with_*` builders set the
/// rest. Fields are public so asymmetric (h ≠ w) geometry can be spelled
/// directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvSpec {
    /// input planes per image (the C of NCHW)
    pub in_channels: usize,
    /// filters in the bank — output planes per image
    pub out_channels: usize,
    pub kernel_h: usize,
    pub kernel_w: usize,
    pub stride_h: usize,
    pub stride_w: usize,
    /// zero-padding added to each side of the input, per axis
    pub pad_h: usize,
    pub pad_w: usize,
    /// tap spacing; 1 = dense kernel (the subsystem is dilation-ready,
    /// the serving CLI currently exposes stride/padding only)
    pub dilation_h: usize,
    pub dilation_w: usize,
}

impl ConvSpec {
    /// A dense stride-1 unpadded spec — the PR 3 geometry, generalized
    /// over channels.
    pub fn new(
        in_channels: usize,
        out_channels: usize,
        kernel_h: usize,
        kernel_w: usize,
    ) -> Self {
        Self {
            in_channels,
            out_channels,
            kernel_h,
            kernel_w,
            stride_h: 1,
            stride_w: 1,
            pad_h: 0,
            pad_w: 0,
            dilation_h: 1,
            dilation_w: 1,
        }
    }

    /// Uniform stride on both axes.
    pub fn with_stride(mut self, stride: usize) -> Self {
        self.stride_h = stride;
        self.stride_w = stride;
        self
    }

    /// Uniform zero-padding on both axes.
    pub fn with_padding(mut self, pad: usize) -> Self {
        self.pad_h = pad;
        self.pad_w = pad;
        self
    }

    /// Uniform dilation on both axes.
    pub fn with_dilation(mut self, dilation: usize) -> Self {
        self.dilation_h = dilation;
        self.dilation_w = dilation;
        self
    }

    /// Taps per output pixel (`C·kh·kw`) — the contraction dimension of
    /// the `(K, T, F)` lowering.
    pub fn taps(&self) -> usize {
        self.in_channels * self.kernel_h * self.kernel_w
    }

    /// Values one NCHW image occupies on the wire (`C·in_h·in_w`).
    pub fn image_len(&self, in_h: usize, in_w: usize) -> usize {
        self.in_channels * in_h * in_w
    }

    /// Values the flattened `[filter][channel][kh][kw]` bank occupies
    /// (`F·C·kh·kw`).
    pub fn bank_len(&self) -> usize {
        self.out_channels * self.taps()
    }

    /// Dilated kernel extent along one axis: `dilation·(k−1) + 1`.
    fn extent(k: usize, dilation: usize) -> usize {
        dilation * (k - 1) + 1
    }

    /// Structural validity: every count that must be positive is.
    pub fn validate(&self) -> Result<(), LinalgError> {
        if self.kernel_h == 0 || self.kernel_w == 0 {
            return Err(LinalgError::EmptyInput { what: "kernel" });
        }
        if self.in_channels == 0 {
            return Err(LinalgError::InvalidConvSpec { field: "in_channels" });
        }
        if self.out_channels == 0 {
            return Err(LinalgError::InvalidConvSpec { field: "out_channels" });
        }
        if self.stride_h == 0 || self.stride_w == 0 {
            return Err(LinalgError::InvalidConvSpec { field: "stride" });
        }
        if self.dilation_h == 0 || self.dilation_w == 0 {
            return Err(LinalgError::InvalidConvSpec { field: "dilation" });
        }
        Ok(())
    }

    fn does_not_fit(&self, in_h: usize, in_w: usize) -> LinalgError {
        LinalgError::KernelDoesNotFit {
            kh: self.kernel_h,
            kw: self.kernel_w,
            in_h,
            in_w,
            stride: (self.stride_h, self.stride_w),
            pad: (self.pad_h, self.pad_w),
            dilation: (self.dilation_h, self.dilation_w),
        }
    }

    /// Validated output map shape for an `in_h×in_w` (per-channel) input:
    /// `out = (in + 2·pad − dilation·(k−1) − 1) / stride + 1` per axis.
    /// The one place this arithmetic happens for the whole subsystem.
    pub fn output_shape(&self, in_h: usize, in_w: usize) -> Result<(usize, usize), LinalgError> {
        self.validate()?;
        if in_h == 0 || in_w == 0 {
            return Err(LinalgError::EmptyInput { what: "input" });
        }
        let eh = Self::extent(self.kernel_h, self.dilation_h);
        let ew = Self::extent(self.kernel_w, self.dilation_w);
        let padded_h = in_h + 2 * self.pad_h;
        let padded_w = in_w + 2 * self.pad_w;
        if padded_h < eh || padded_w < ew {
            return Err(self.does_not_fit(in_h, in_w));
        }
        Ok((
            (padded_h - eh) / self.stride_h + 1,
            (padded_w - ew) / self.stride_w + 1,
        ))
    }

    /// Output pixels per image (`out_h·out_w`), validated.
    pub fn output_pixels(&self, in_h: usize, in_w: usize) -> Result<usize, LinalgError> {
        self.output_shape(in_h, in_w).map(|(h, w)| h * w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_reproduce_valid_mode_shapes() {
        let spec = ConvSpec::new(1, 1, 3, 3);
        assert_eq!(spec.output_shape(8, 10), Ok((6, 8)));
        assert_eq!(spec.output_shape(3, 3), Ok((1, 1)));
        assert_eq!(spec.taps(), 9);
        assert_eq!(spec.image_len(8, 10), 80);
        assert_eq!(spec.bank_len(), 9);
    }

    #[test]
    fn stride_padding_dilation_shapes_match_hand_calc() {
        // 3×3 stride 2, pad 1 over 28×28: (28 + 2 − 3)/2 + 1 = 14
        let spec = ConvSpec::new(3, 8, 3, 3).with_stride(2).with_padding(1);
        assert_eq!(spec.output_shape(28, 28), Ok((14, 14)));
        assert_eq!(spec.taps(), 27);
        assert_eq!(spec.bank_len(), 8 * 27);

        // dilation 2 makes a 3-tap kernel span 5 samples
        let spec = ConvSpec::new(1, 1, 3, 3).with_dilation(2);
        assert_eq!(spec.output_shape(5, 5), Ok((1, 1)));
        assert_eq!(spec.output_shape(7, 9), Ok((3, 5)));

        // asymmetric geometry through the public fields
        let spec = ConvSpec {
            stride_h: 3,
            pad_w: 2,
            ..ConvSpec::new(2, 4, 2, 5)
        };
        // h: (9 − 2)/3 + 1 = 3; w: (6 + 4 − 5)/1 + 1 = 6
        assert_eq!(spec.output_shape(9, 6), Ok((3, 6)));
    }

    #[test]
    fn padding_can_rescue_an_otherwise_too_small_input() {
        let unpadded = ConvSpec::new(1, 1, 5, 5);
        assert!(unpadded.output_shape(3, 3).is_err());
        let padded = ConvSpec::new(1, 1, 5, 5).with_padding(1);
        assert_eq!(padded.output_shape(3, 3), Ok((1, 1)));
    }

    #[test]
    fn errors_carry_the_full_geometry() {
        let spec = ConvSpec::new(2, 4, 5, 5).with_stride(2).with_padding(1).with_dilation(2);
        // dilated extent 9 > 3 + 2·1
        assert_eq!(
            spec.output_shape(3, 3),
            Err(LinalgError::KernelDoesNotFit {
                kh: 5,
                kw: 5,
                in_h: 3,
                in_w: 3,
                stride: (2, 2),
                pad: (1, 1),
                dilation: (2, 2),
            })
        );
        let msg = spec.output_shape(3, 3).unwrap_err().to_string();
        assert!(msg.contains("stride 2x2"), "{msg}");
        assert!(msg.contains("padding 1x1"), "{msg}");
        assert!(msg.contains("dilation 2x2"), "{msg}");

        assert_eq!(
            ConvSpec::new(0, 4, 3, 3).output_shape(8, 8),
            Err(LinalgError::InvalidConvSpec { field: "in_channels" })
        );
        assert_eq!(
            ConvSpec::new(1, 0, 3, 3).output_shape(8, 8),
            Err(LinalgError::InvalidConvSpec { field: "out_channels" })
        );
        assert_eq!(
            ConvSpec::new(1, 1, 3, 3).with_stride(0).output_shape(8, 8),
            Err(LinalgError::InvalidConvSpec { field: "stride" })
        );
        assert_eq!(
            ConvSpec::new(1, 1, 3, 3).with_dilation(0).output_shape(8, 8),
            Err(LinalgError::InvalidConvSpec { field: "dilation" })
        );
        assert_eq!(
            ConvSpec::new(1, 1, 0, 3).output_shape(8, 8),
            Err(LinalgError::EmptyInput { what: "kernel" })
        );
        assert_eq!(
            ConvSpec::new(1, 1, 3, 3).output_shape(0, 8),
            Err(LinalgError::EmptyInput { what: "input" })
        );
    }
}
