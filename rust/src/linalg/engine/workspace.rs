//! Allocation-free workspace arenas for the lowering subsystem.
//!
//! Every serving batch through the PR 3 lowering re-allocated its scratch:
//! the im2col patch matrix, the GEMM output, the row-correction vector,
//! the CPM3 derived operand and pass planes — each a fresh `Vec` on the
//! hot path, freed microseconds later. [`EngineWorkspace`] is the arena
//! those buffers live in instead: callers *check out* a buffer of the
//! length they need and *give it back* when done, and because a serving
//! worker sees the same shapes batch after batch, every checkout after
//! the first warm-up batch is served from retained capacity — the steady
//! state performs **zero** heap allocations (single-threaded engine
//! config; the `std::thread::scope` driver allocates per spawn by
//! construction, so the threaded path trades that guarantee for
//! parallelism).
//!
//! The arena is deliberately dumb: a free list of `Vec<T>`s matched
//! best-fit by capacity. No keys, no lifetimes, no unsafe — a checked-out
//! buffer is an owned `Vec<T>` (so it can be wrapped in a
//! [`Matrix`](crate::linalg::Matrix) via `from_vec`/`into_data` without
//! copying), and forgetting to give one back merely costs its reuse, not
//! correctness. Each worker of a serving pool owns its own workspace
//! (`EngineWorkspace` is `Send` — plain `Vec`s), so the pool stays
//! `Send`-clean with no cross-worker locking.
//!
//! [`Self::grows`](EngineWorkspace::grows) counts the checkouts that had
//! to touch the allocator; the `blocked_conv` bench and the
//! `workspace_alloc` integration test pin the steady state to zero with
//! a counting global allocator on top.

/// A reusable buffer arena: checked-out `Vec<T>`s returned to a free
/// list, matched best-fit by capacity on the next checkout.
#[derive(Debug, Default)]
pub struct EngineWorkspace<T> {
    free: Vec<Vec<T>>,
    checkouts: u64,
    grows: u64,
}

impl<T: Copy + Default> EngineWorkspace<T> {
    /// An empty arena; buffers are created on first checkout (warm-up)
    /// and retained from then on.
    pub fn new() -> Self {
        Self { free: Vec::new(), checkouts: 0, grows: 0 }
    }

    /// Check out a buffer of exactly `len` elements with *unspecified*
    /// contents — every consumer fully overwrites its checkout (the NCHW
    /// extraction writes padding zeros explicitly, the matmul core seeds
    /// every output element), so a warmed same-length checkout is a
    /// write-free no-op, not a redundant memset of the hot path's
    /// largest buffers. Freshly grown elements do arrive as
    /// `T::default()` (that is `Vec::resize` filling the delta). Reuses
    /// the best-fitting retained buffer: among free buffers that already
    /// hold `len`, the smallest; if none fits, the largest is grown
    /// (counted in [`Self::grows`]).
    pub fn checkout(&mut self, len: usize) -> Vec<T> {
        self.checkouts += 1;
        let mut pick: Option<(usize, usize)> = None; // (index, capacity)
        for (i, buf) in self.free.iter().enumerate() {
            let cap = buf.capacity();
            pick = match pick {
                None => Some((i, cap)),
                Some((pi, pc)) => {
                    let better = match (cap >= len, pc >= len) {
                        (true, false) => true,
                        (false, true) => false,
                        (true, true) => cap < pc,
                        (false, false) => cap > pc,
                    };
                    if better {
                        Some((i, cap))
                    } else {
                        Some((pi, pc))
                    }
                }
            };
        }
        let mut buf = match pick {
            Some((i, _)) => self.free.swap_remove(i),
            None => Vec::new(),
        };
        if buf.capacity() < len {
            self.grows += 1;
        }
        // no clear(): a same-length reuse truncates/extends nothing and
        // writes nothing; only genuinely new elements get default-filled
        buf.resize(len, T::default());
        buf
    }

    /// Return a buffer to the free list for the next checkout. Accepts
    /// any `Vec` (including one recovered from a `Matrix` via
    /// `into_data`); its contents are irrelevant, only its capacity is
    /// retained.
    pub fn give_back(&mut self, buf: Vec<T>) {
        self.free.push(buf);
    }

    /// Total checkouts served over the arena's lifetime.
    pub fn checkouts(&self) -> u64 {
        self.checkouts
    }

    /// Checkouts that had to grow a buffer (allocate). After warm-up this
    /// must stop advancing — the steady-state-zero-allocations claim, as
    /// seen from inside the arena.
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// Buffers currently retained on the free list.
    pub fn retained(&self) -> usize {
        self.free.len()
    }

    /// Total elements of retained capacity (the arena's memory footprint
    /// in units of `T`).
    pub fn retained_capacity(&self) -> usize {
        self.free.iter().map(Vec::capacity).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_has_exact_length_and_default_fills_only_growth() {
        let mut ws = EngineWorkspace::<i64>::new();
        let mut buf = ws.checkout(7);
        // a fresh buffer's elements are all newly grown, hence default
        assert_eq!(buf, vec![0i64; 7]);
        buf.iter_mut().for_each(|v| *v = 9);
        ws.give_back(buf);
        // recycled contents are unspecified (callers fully overwrite);
        // only the length contract holds — and shrinking writes nothing
        let again = ws.checkout(5);
        assert_eq!(again.len(), 5);
        ws.give_back(again);
        // growing past the retained *length* default-fills the delta
        let grown = ws.checkout(7);
        assert_eq!(grown.len(), 7);
        assert_eq!(ws.checkouts(), 3);
        assert_eq!(ws.grows(), 1, "reuse within capacity must not count as growth");
    }

    #[test]
    fn steady_state_stops_growing() {
        let mut ws = EngineWorkspace::<i64>::new();
        // the apply_batch_ws shape pattern: one large, one mid, one small
        for _ in 0..4 {
            let a = ws.checkout(640);
            let b = ws.checkout(120);
            let c = ws.checkout(16);
            ws.give_back(c);
            ws.give_back(a);
            ws.give_back(b);
        }
        assert_eq!(ws.checkouts(), 12);
        assert_eq!(ws.grows(), 3, "only the warm-up round may allocate");
        assert_eq!(ws.retained(), 3);
        assert!(ws.retained_capacity() >= 640 + 120 + 16);
    }

    #[test]
    fn best_fit_prefers_the_smallest_sufficient_buffer() {
        let mut ws = EngineWorkspace::<i64>::new();
        let big = ws.checkout(1000);
        let small = ws.checkout(10);
        ws.give_back(big);
        ws.give_back(small);
        // a 10-element request must take the 10-capacity buffer, not
        // shred the 1000-capacity one
        let got = ws.checkout(10);
        assert!(got.capacity() < 1000);
        assert_eq!(ws.grows(), 2);
        // and the big request still finds the big buffer
        let got_big = ws.checkout(1000);
        assert!(got_big.capacity() >= 1000);
        assert_eq!(ws.grows(), 2, "warm big buffer must not re-grow");
    }

    #[test]
    fn growing_reuses_the_largest_free_buffer() {
        let mut ws = EngineWorkspace::<i64>::new();
        let a = ws.checkout(100);
        ws.give_back(a);
        // nothing fits 200: the 100-capacity buffer is grown, counted
        let b = ws.checkout(200);
        assert_eq!(b.len(), 200);
        assert_eq!(ws.grows(), 2);
        assert_eq!(ws.retained(), 0);
    }
}
