//! Blocked CPM3 complex matmul: three square passes on plane-split data.
//!
//! The reference [`cmatmul_cpm3`](crate::linalg::complex::cmatmul_cpm3)
//! walks `Complex<i64>` elements to make the §9 ledger auditable; this
//! module runs the same arithmetic *fast* by storing complex matrices as
//! separate re/im planes ([`CPlanes`]) and observing that the CPM3
//! decomposition (eq. 32–35) is exactly three *real* products, each of
//! which the blocked square core already computes with squares only:
//!
//! ```text
//! Z = X·Y,  X = A + jB,  Y = C + jS        (planes A,B,C,S)
//! M1 = (A + B)·C        — the shared (c+a+b)² pass
//! M2 = B·(C + S)        — the (b+c+s)² pass
//! M3 = A·(S − C)        — the (a+s−c)² pass
//! Z_re = M1 − M2,   Z_im = M1 + M3
//! ```
//!
//! Each pass runs through [`matmul_square_core`]: eq. (4) with its own
//! rank-1 row/column corrections, cache-blocked and row-partition
//! threaded. Squares spent: `3·(M·N·P + M·N + N·P)` — identical to the
//! reference CPM3 ledger (§9), because the three passes' corrections *are*
//! the `Sab/Sba/Scs/Ssc` terms of eq. (33)/(35) regrouped per pass.
//!
//! [`PreparedCpm3`] is the §3 constant-operand case for a fixed complex
//! weight matrix (beamforming / matched filters over QPSK symbols): the
//! three derived column operands `C`, `C+S`, `S−C` and their correction
//! caches are computed once per model and shared by all three passes of
//! every request — and, via `new_shared`, by every worker of a pool.

use std::sync::Arc;

use super::super::counts::OpCounts;
use super::super::matrix::Matrix;
use super::super::LinalgError;
use super::blocked::{
    col_corrections_flat, matmul_square_core, matmul_square_core_into, matmul_square_tile_into,
    row_corrections_flat, row_corrections_into, square_matmul_tile_ledger, EngineConfig,
};
use super::im2col::im2col;
use super::workspace::EngineWorkspace;
use super::SquareScalar;

/// A complex matrix stored as two same-shaped real planes — the storage
/// the lowering (and the serving wire format) uses, so the square passes
/// stream contiguous real rows instead of strided `Complex` fields.
#[derive(Debug, Clone, PartialEq)]
pub struct CPlanes<T> {
    pub re: Matrix<T>,
    pub im: Matrix<T>,
}

impl<T: SquareScalar> CPlanes<T> {
    /// Pair two planes; they must agree on shape.
    pub fn new(re: Matrix<T>, im: Matrix<T>) -> Result<Self, LinalgError> {
        if (re.rows, re.cols) != (im.rows, im.cols) {
            return Err(LinalgError::ShapeMismatch {
                what: "complex planes",
                expected: (re.rows, re.cols),
                got: (im.rows, im.cols),
            });
        }
        Ok(Self { re, im })
    }

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { re: Matrix::zeros(rows, cols), im: Matrix::zeros(rows, cols) }
    }

    pub fn rows(&self) -> usize {
        self.re.rows
    }

    pub fn cols(&self) -> usize {
        self.re.cols
    }

    /// Re-check the pairing invariant — the fields are public (the
    /// executors build planes in place), so the fallible entry points
    /// validate rather than trust, keeping a mismatched pair a typed
    /// `Err` instead of a worker-killing `plane_add` panic.
    fn check(&self) -> Result<(), LinalgError> {
        if (self.re.rows, self.re.cols) != (self.im.rows, self.im.cols) {
            return Err(LinalgError::ShapeMismatch {
                what: "complex planes",
                expected: (self.re.rows, self.re.cols),
                got: (self.im.rows, self.im.cols),
            });
        }
        Ok(())
    }
}

/// Elementwise plane sum — forming the derived operands of the three
/// passes (`A+B`, `C+S`).
pub fn plane_add<T: SquareScalar>(a: &Matrix<T>, b: &Matrix<T>) -> Matrix<T> {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "plane shape mismatch");
    Matrix::from_vec(
        a.rows,
        a.cols,
        a.data().iter().zip(b.data()).map(|(&x, &y)| x + y).collect(),
    )
}

/// Elementwise plane difference (`S−C`, and the `M1 − M2` combination).
pub fn plane_sub<T: SquareScalar>(a: &Matrix<T>, b: &Matrix<T>) -> Matrix<T> {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "plane shape mismatch");
    Matrix::from_vec(
        a.rows,
        a.cols,
        a.data().iter().zip(b.data()).map(|(&x, &y)| x - y).collect(),
    )
}

/// Hoisted ledger of the full blocked CPM3 (both operands fresh): three
/// `(M,N,P)` square passes plus the plane-forming and combining adds.
/// Squares match the reference CPM3 claim (§9): `3·(MNP + MN + NP)`.
pub fn cpm3_blocked_ledger(m: usize, n: usize, p: usize) -> OpCounts {
    let (m, n, p) = (m as u64, n as u64, p as u64);
    OpCounts {
        mults: 0,
        squares: 3 * (m * n * p + m * n + n * p),
        // forming A+B (mn), C+S and S−C (2np); per pass: mn + np correction
        // adds, mp seed adds, 2mnp window adds; combining M1∓M2/M3: 2mp
        adds: 4 * m * n + 5 * n * p + 6 * m * n * p + 5 * m * p,
        shifts: 3 * m * p,
    }
}

/// Hoisted per-call ledger against a [`PreparedCpm3`] operand: the `3·N·P`
/// column-correction squares and the `5·N·P` preparation adds are gone —
/// the §3 amortisation, three passes at once.
pub fn cpm3_prepared_ledger(m: usize, n: usize, p: usize) -> OpCounts {
    let (m, n, p) = (m as u64, n as u64, p as u64);
    OpCounts {
        mults: 0,
        squares: 3 * (m * n * p + m * n),
        adds: 4 * m * n + 6 * m * n * p + 5 * m * p,
        shifts: 3 * m * p,
    }
}

/// A constant complex right-hand operand, lowered and prepared once: the
/// three derived real operands with their column-correction caches.
#[derive(Debug, Clone)]
pub struct PreparedCpm3<T> {
    /// `C` (the re plane of Y) and its corrections — pass 1
    q1: Matrix<T>,
    sb1: Vec<T>,
    /// `C + S` — pass 2
    q2: Matrix<T>,
    sb2: Vec<T>,
    /// `S − C` — pass 3
    q3: Matrix<T>,
    sb3: Vec<T>,
}

impl<T: SquareScalar> PreparedCpm3<T> {
    /// Validate, derive and cache the three pass operands and their
    /// corrections. The returned ledger is the one-time cost: `3·N·P`
    /// squares (the §3/§9 correction amortisation) and `5·N·P` adds.
    pub fn new(y: &CPlanes<T>) -> Result<(Self, OpCounts), LinalgError> {
        y.check()?;
        let (n, p) = (y.rows(), y.cols());
        let q1 = y.re.clone();
        let q2 = plane_add(&y.re, &y.im);
        let q3 = plane_sub(&y.im, &y.re);
        let sb1 = col_corrections_flat(&q1);
        let sb2 = col_corrections_flat(&q2);
        let sb3 = col_corrections_flat(&q3);
        let np = (n * p) as u64;
        let prep = OpCounts { squares: 3 * np, adds: 5 * np, ..OpCounts::ZERO };
        Ok((Self { q1, sb1, q2, sb2, q3, sb3 }, prep))
    }

    /// Prepare and wrap for sharing across a serving pool.
    pub fn new_shared(y: &CPlanes<T>) -> Result<(Arc<Self>, OpCounts), LinalgError> {
        let (prep, ops) = Self::new(y)?;
        Ok((Arc::new(prep), ops))
    }

    /// Input features a request row must carry (rows of Y).
    pub fn in_features(&self) -> usize {
        self.q1.rows
    }

    /// Output features per request row (columns of Y).
    pub fn out_features(&self) -> usize {
        self.q1.cols
    }

    /// The original re plane of Y (`C` — cached verbatim as pass 1's
    /// operand), for direct-twin shadows over the same weights.
    pub fn re_plane(&self) -> &Matrix<T> {
        &self.q1
    }

    /// The original im plane of Y, recovered as `(C+S) − C`.
    pub fn im_plane(&self) -> Matrix<T> {
        plane_sub(&self.q2, &self.q1)
    }

    /// [`Self::mul`] with every scratch plane drawn from an
    /// [`EngineWorkspace`]: the derived `A+B` operand, the three
    /// row-correction vectors and the three pass planes are reused
    /// checkouts, and the result planes land in `z_re`/`z_im` (cleared +
    /// resized, row-major `M×P`) — zero heap allocations once warm with
    /// `cfg.threads == 1`. Values and ledger are identical to
    /// [`Self::mul`].
    pub fn mul_into(
        &self,
        x: &CPlanes<T>,
        cfg: &EngineConfig,
        ws: &mut EngineWorkspace<T>,
        z_re: &mut Vec<T>,
        z_im: &mut Vec<T>,
    ) -> Result<OpCounts, LinalgError> {
        x.check()?;
        let (m, n) = (x.rows(), x.cols());
        if n != self.in_features() {
            return Err(LinalgError::ContractionMismatch {
                left_cols: n,
                right_rows: self.in_features(),
            });
        }
        let p = self.out_features();

        // derived row operand A+B and the per-request corrections
        let mut p1 = ws.checkout(m * n);
        for ((d, &a), &b) in p1.iter_mut().zip(x.re.data()).zip(x.im.data()) {
            *d = a + b;
        }
        let p1 = Matrix::from_vec(m, n, p1);
        let mut sa1 = ws.checkout(m);
        row_corrections_into(&p1, &mut sa1);
        let mut sa2 = ws.checkout(m);
        row_corrections_into(&x.im, &mut sa2);
        let mut sa3 = ws.checkout(m);
        row_corrections_into(&x.re, &mut sa3);

        // the three square passes — all the multiplicative work
        let mut m1 = ws.checkout(m * p);
        matmul_square_core_into(&mut m1, &p1, &self.q1, &sa1, &self.sb1, cfg);
        let mut m2 = ws.checkout(m * p);
        matmul_square_core_into(&mut m2, &x.im, &self.q2, &sa2, &self.sb2, cfg);
        let mut m3 = ws.checkout(m * p);
        matmul_square_core_into(&mut m3, &x.re, &self.q3, &sa3, &self.sb3, cfg);

        z_re.clear();
        z_re.extend(m1.iter().zip(&m2).map(|(&u, &v)| u - v));
        z_im.clear();
        z_im.extend(m1.iter().zip(&m3).map(|(&u, &v)| u + v));

        ws.give_back(p1.into_data());
        ws.give_back(sa1);
        ws.give_back(sa2);
        ws.give_back(sa3);
        ws.give_back(m1);
        ws.give_back(m2);
        ws.give_back(m3);
        Ok(cpm3_prepared_ledger(m, n, p))
    }

    /// §3.3 tile entry: compute output rows `[i0, i1)` of `Z = X·Y` as
    /// three square-pass *tiles* against the cached operands, writing the
    /// partition's row-major storage into `z_re_tile`/`z_im_tile`
    /// (disjoint sub-slices of the request's output planes, so concurrent
    /// tiles need no locking). The caller hoists the per-request state
    /// ONCE — the derived `A+B` plane (`x_sum`) and the three full-row
    /// corrections `sa_*` via [`row_corrections_into`] — exactly as the
    /// paper prescribes for tiled operation; this method never recomputes
    /// them. Values are byte-identical to [`Self::mul_into`]'s rows
    /// because each pass runs the same per-row kernel. The returned
    /// ledger is the tile's marginal cost: three
    /// [`square_matmul_tile_ledger`]s plus the `2·mi·P` combining adds.
    #[allow(clippy::too_many_arguments)]
    pub fn mul_tile_into(
        &self,
        x_sum: &Matrix<T>,
        x_im: &Matrix<T>,
        x_re: &Matrix<T>,
        sa_sum: &[T],
        sa_im: &[T],
        sa_re: &[T],
        i0: usize,
        i1: usize,
        cfg: &EngineConfig,
        ws: &mut EngineWorkspace<T>,
        z_re_tile: &mut [T],
        z_im_tile: &mut [T],
    ) -> Result<OpCounts, LinalgError> {
        let n = x_sum.cols;
        if n != self.in_features() {
            return Err(LinalgError::ContractionMismatch {
                left_cols: n,
                right_rows: self.in_features(),
            });
        }
        let p = self.out_features();
        let mi = i1 - i0;
        let mut m1 = ws.checkout(mi * p);
        matmul_square_tile_into(x_sum, &self.q1, sa_sum, &self.sb1, i0, i1, &mut m1, cfg);
        let mut m2 = ws.checkout(mi * p);
        matmul_square_tile_into(x_im, &self.q2, sa_im, &self.sb2, i0, i1, &mut m2, cfg);
        let mut m3 = ws.checkout(mi * p);
        matmul_square_tile_into(x_re, &self.q3, sa_re, &self.sb3, i0, i1, &mut m3, cfg);

        for ((d, &u), &v) in z_re_tile.iter_mut().zip(&m1).zip(&m2) {
            *d = u - v;
        }
        for ((d, &u), &v) in z_im_tile.iter_mut().zip(&m1).zip(&m3) {
            *d = u + v;
        }

        ws.give_back(m1);
        ws.give_back(m2);
        ws.give_back(m3);
        let mut ops = square_matmul_tile_ledger(mi, n, p)
            + square_matmul_tile_ledger(mi, n, p)
            + square_matmul_tile_ledger(mi, n, p);
        ops.add_n(2 * (mi * p) as u64);
        Ok(ops)
    }

    /// `Z = X·Y` against the prepared operand: three blocked square
    /// passes reusing the cached column corrections. Per-call ledger is
    /// [`cpm3_prepared_ledger`].
    pub fn mul(
        &self,
        x: &CPlanes<T>,
        cfg: &EngineConfig,
    ) -> Result<(CPlanes<T>, OpCounts), LinalgError> {
        x.check()?;
        let (m, n) = (x.rows(), x.cols());
        if n != self.in_features() {
            return Err(LinalgError::ContractionMismatch {
                left_cols: n,
                right_rows: self.in_features(),
            });
        }
        let p = self.out_features();

        // derived row operands and their corrections (per request)
        let p1 = plane_add(&x.re, &x.im);
        let sa1 = row_corrections_flat(&p1);
        let sa2 = row_corrections_flat(&x.im);
        let sa3 = row_corrections_flat(&x.re);

        // the three square passes — all the multiplicative work
        let m1 = matmul_square_core(&p1, &self.q1, &sa1, &self.sb1, cfg);
        let m2 = matmul_square_core(&x.im, &self.q2, &sa2, &self.sb2, cfg);
        let m3 = matmul_square_core(&x.re, &self.q3, &sa3, &self.sb3, cfg);

        let z = CPlanes { re: plane_sub(&m1, &m2), im: plane_add(&m1, &m3) };
        Ok((z, cpm3_prepared_ledger(m, n, p)))
    }
}

/// Blocked (and, with `cfg.threads > 1`, threaded) CPM3 complex matmul on
/// plane-split operands: `Z = X·Y` bit-exactly equal to
/// [`cmatmul_direct`](crate::linalg::complex::cmatmul_direct) for `i64`
/// (each pass's trailing ÷2 is exact). One-shot form: derives and ledgers
/// the Y-side caches too ([`cpm3_blocked_ledger`]).
pub fn cmatmul_cpm3_blocked<T: SquareScalar>(
    x: &CPlanes<T>,
    y: &CPlanes<T>,
    cfg: &EngineConfig,
) -> Result<(CPlanes<T>, OpCounts), LinalgError> {
    y.check()?;
    if x.cols() != y.rows() {
        return Err(LinalgError::ContractionMismatch {
            left_cols: x.cols(),
            right_rows: y.rows(),
        });
    }
    let (prep, prep_ops) = PreparedCpm3::new(y)?;
    let (z, call_ops) = prep.mul(x, cfg)?;
    let total = call_ops + prep_ops;
    debug_assert_eq!(total, cpm3_blocked_ledger(x.rows(), x.cols(), y.cols()));
    Ok((z, total))
}

/// Hoisted ledger of the full blocked CPM (4-square, §6) twin: four
/// `(M,N,P)` square passes over the raw planes. Squares match the
/// reference [`cmatmul_cpm`](crate::linalg::complex::cmatmul_cpm) claim
/// (eq. 20): `4·MNP + 2·MN + 2·NP` — one square per real product plus the
/// reusable row/column energy corrections, each plane corrected once and
/// shared by its two passes.
pub fn cpm_blocked_ledger(m: usize, n: usize, p: usize) -> OpCounts {
    let (m, n, p) = (m as u64, n as u64, p as u64);
    OpCounts {
        mults: 0,
        squares: 4 * m * n * p + 2 * m * n + 2 * n * p,
        // corrections: 2mn + 2np; per pass: mp seed + 2mnp window adds;
        // combining Z_re = M1−M2, Z_im = M3+M4: 2mp
        adds: 2 * m * n + 2 * n * p + 8 * m * n * p + 6 * m * p,
        // each of the four passes carries its own exact ÷2 (the reference
        // CPM folds the four squares per output into two shifts; the
        // square *budget* — the §6 claim — is identical)
        shifts: 4 * m * p,
    }
}

/// Hoisted per-call ledger against a [`PreparedCpm`] operand: the `2·N·P`
/// column-correction squares/adds are amortised away (§3).
pub fn cpm_prepared_ledger(m: usize, n: usize, p: usize) -> OpCounts {
    let (m, n, p) = (m as u64, n as u64, p as u64);
    OpCounts {
        mults: 0,
        squares: 4 * m * n * p + 2 * m * n,
        adds: 2 * m * n + 8 * m * n * p + 6 * m * p,
        shifts: 4 * m * p,
    }
}

/// A constant complex right-hand operand prepared for the 4-square CPM
/// (§6) lowering — the comparison twin [`PreparedCpm3`] is measured
/// against. CPM needs no derived operands: the four passes
/// `M1 = A·C, M2 = B·S, M3 = B·C, M4 = A·S`
/// (`Z_re = M1 − M2, Z_im = M3 + M4`) run on the raw planes, so only the
/// two column-correction caches are stored.
#[derive(Debug, Clone)]
pub struct PreparedCpm<T> {
    /// `C` (the re plane of Y) — passes 1 and 3
    c: Matrix<T>,
    sc: Vec<T>,
    /// `S` (the im plane of Y) — passes 2 and 4
    s: Matrix<T>,
    ss: Vec<T>,
}

impl<T: SquareScalar> PreparedCpm<T> {
    /// Validate and cache the two plane operands and their corrections.
    /// One-time ledger: `2·N·P` squares and adds.
    pub fn new(y: &CPlanes<T>) -> Result<(Self, OpCounts), LinalgError> {
        y.check()?;
        let np = (y.rows() * y.cols()) as u64;
        let sc = col_corrections_flat(&y.re);
        let ss = col_corrections_flat(&y.im);
        let prep = OpCounts { squares: 2 * np, adds: 2 * np, ..OpCounts::ZERO };
        Ok((Self { c: y.re.clone(), sc, s: y.im.clone(), ss }, prep))
    }

    /// Prepare and wrap for sharing across a serving pool.
    pub fn new_shared(y: &CPlanes<T>) -> Result<(Arc<Self>, OpCounts), LinalgError> {
        let (prep, ops) = Self::new(y)?;
        Ok((Arc::new(prep), ops))
    }

    /// Input features a request row must carry (rows of Y).
    pub fn in_features(&self) -> usize {
        self.c.rows
    }

    /// Output features per request row (columns of Y).
    pub fn out_features(&self) -> usize {
        self.c.cols
    }

    /// `Z = X·Y` via four blocked square passes reusing the cached column
    /// corrections; per-call ledger [`cpm_prepared_ledger`]. Each plane's
    /// row corrections are computed once and shared by its two passes —
    /// that sharing is exactly why eq. 20 reads `2·MN`, not `4·MN`.
    pub fn mul(
        &self,
        x: &CPlanes<T>,
        cfg: &EngineConfig,
    ) -> Result<(CPlanes<T>, OpCounts), LinalgError> {
        x.check()?;
        let (m, n) = (x.rows(), x.cols());
        if n != self.in_features() {
            return Err(LinalgError::ContractionMismatch {
                left_cols: n,
                right_rows: self.in_features(),
            });
        }
        let p = self.out_features();

        let sa = row_corrections_flat(&x.re);
        let sb = row_corrections_flat(&x.im);

        let m1 = matmul_square_core(&x.re, &self.c, &sa, &self.sc, cfg); // A·C
        let m2 = matmul_square_core(&x.im, &self.s, &sb, &self.ss, cfg); // B·S
        let m3 = matmul_square_core(&x.im, &self.c, &sb, &self.sc, cfg); // B·C
        let m4 = matmul_square_core(&x.re, &self.s, &sa, &self.ss, cfg); // A·S

        let z = CPlanes { re: plane_sub(&m1, &m2), im: plane_add(&m3, &m4) };
        Ok((z, cpm_prepared_ledger(m, n, p)))
    }
}

/// Blocked CPM (4-square) complex matmul on plane-split operands — the
/// §6 twin of [`cmatmul_cpm3_blocked`], kept so the benches can measure
/// the 4-square vs 3-square budget trade on the same engine. One-shot
/// form: derives and ledgers the Y-side caches too
/// ([`cpm_blocked_ledger`]).
pub fn cmatmul_cpm_blocked<T: SquareScalar>(
    x: &CPlanes<T>,
    y: &CPlanes<T>,
    cfg: &EngineConfig,
) -> Result<(CPlanes<T>, OpCounts), LinalgError> {
    y.check()?;
    if x.cols() != y.rows() {
        return Err(LinalgError::ContractionMismatch {
            left_cols: x.cols(),
            right_rows: y.rows(),
        });
    }
    let (prep, prep_ops) = PreparedCpm::new(y)?;
    let (z, call_ops) = prep.mul(x, cfg)?;
    let total = call_ops + prep_ops;
    debug_assert_eq!(total, cpm_blocked_ledger(x.rows(), x.cols(), y.cols()));
    Ok((z, total))
}

/// A constant complex FIR kernel prepared for the three-pass CPM3
/// lowering: the correlation `y_k = Σ_i w_i·x_{i+k}` is a
/// `(K, N, 1)` complex matmul of the signal's patch planes against the
/// kernel column, so it rides the exact [`PreparedCpm3`] machinery — the
/// kernel's three derived operands and corrections are cached once per
/// filter (per pool) and reused for every window of every signal.
#[derive(Debug, Clone)]
pub struct PreparedCpm3Conv1d<T> {
    taps: usize,
    prep: PreparedCpm3<T>,
}

impl<T: SquareScalar> PreparedCpm3Conv1d<T> {
    /// Prepare a complex kernel from its planes. One-time ledger: the
    /// `3·N` correction squares (`P = 1`) of [`PreparedCpm3::new`].
    pub fn new(w_re: &[T], w_im: &[T]) -> Result<(Self, OpCounts), LinalgError> {
        if w_re.is_empty() {
            return Err(LinalgError::EmptyInput { what: "kernel" });
        }
        if w_re.len() != w_im.len() {
            return Err(LinalgError::ShapeMismatch {
                what: "kernel planes",
                expected: (1, w_re.len()),
                got: (1, w_im.len()),
            });
        }
        let n = w_re.len();
        let y = CPlanes::new(
            Matrix::from_vec(n, 1, w_re.to_vec()),
            Matrix::from_vec(n, 1, w_im.to_vec()),
        )?;
        let (prep, ops) = PreparedCpm3::new(&y)?;
        Ok((Self { taps: n, prep }, ops))
    }

    pub fn taps(&self) -> usize {
        self.taps
    }

    /// Correlate the prepared kernel over a signal given as planes:
    /// extract the `(K, N)` patch planes (pure data movement, the 1-D
    /// im2col), run the three square passes, return the output planes.
    /// Per-call ledger is [`cpm3_prepared_ledger`]`(K, N, 1)`.
    pub fn apply(
        &self,
        x_re: &[T],
        x_im: &[T],
        cfg: &EngineConfig,
    ) -> Result<(Vec<T>, Vec<T>, OpCounts), LinalgError> {
        if x_re.len() != x_im.len() {
            return Err(LinalgError::ShapeMismatch {
                what: "signal planes",
                expected: (1, x_re.len()),
                got: (1, x_im.len()),
            });
        }
        if x_re.is_empty() {
            return Err(LinalgError::EmptyInput { what: "input" });
        }
        if x_re.len() < self.taps {
            // the 1-D framing of the fit error: a 1×N kernel over a 1×L
            // signal, default stride/pad/dilation
            return Err(LinalgError::KernelDoesNotFit {
                kh: 1,
                kw: self.taps,
                in_h: 1,
                in_w: x_re.len(),
                stride: (1, 1),
                pad: (0, 0),
                dilation: (1, 1),
            });
        }
        let k_out = x_re.len() - self.taps + 1;
        let a_re = im2col(&Matrix::from_vec(1, x_re.len(), x_re.to_vec()), 1, self.taps);
        let a_im = im2col(&Matrix::from_vec(1, x_im.len(), x_im.to_vec()), 1, self.taps);
        let xp = CPlanes { re: a_re, im: a_im };
        let (z, ops) = self.prep.mul(&xp, cfg)?;
        debug_assert_eq!(ops, cpm3_prepared_ledger(k_out, self.taps, 1));
        debug_assert_eq!(z.rows(), k_out);
        Ok((z.re.into_data(), z.im.into_data(), ops))
    }
}

/// One-shot blocked CPM3 1-D complex correlation (the ROADMAP follow-on):
/// `cconv1d_cpm3` lowered onto the blocked three-pass machinery. Values
/// are identical to
/// [`cconv1d_direct`](crate::linalg::conv::cconv1d_direct); the ledger is
/// the lowering's own full budget [`cpm3_blocked_ledger`]`(K, N, 1)` —
/// the matmul framing pays per-window row corrections where the Fig. 14
/// streaming engine shares per-sample squares, and in exchange inherits
/// the cache blocking, threading and §3 kernel caching of the matmul
/// core.
pub fn cconv1d_cpm3_blocked<T: SquareScalar>(
    w_re: &[T],
    w_im: &[T],
    x_re: &[T],
    x_im: &[T],
    cfg: &EngineConfig,
) -> Result<(Vec<T>, Vec<T>, OpCounts), LinalgError> {
    let (prep, prep_ops) = PreparedCpm3Conv1d::new(w_re, w_im)?;
    let (re, im, call_ops) = prep.apply(x_re, x_im, cfg)?;
    let total = call_ops + prep_ops;
    debug_assert_eq!(
        total,
        cpm3_blocked_ledger(x_re.len() - w_re.len() + 1, w_re.len(), 1)
    );
    Ok((re, im, total))
}

#[cfg(test)]
mod tests {
    use super::super::super::complex::{
        cmatmul_cpm, cmatmul_cpm3, cmatmul_direct, to_planes, CMatrix,
    };
    use super::*;
    use crate::arith::Complex;
    use crate::testkit::{forall, Rng};

    fn tiny_cfg(threads: usize) -> EngineConfig {
        EngineConfig { block_k: 3, block_n: 5, threads }
    }

    fn random_c(rng: &mut Rng, r: usize, c: usize, lim: i64) -> CMatrix {
        CMatrix::from_fn(r, c, |_, _| {
            Complex::new(rng.i64_in(-lim, lim), rng.i64_in(-lim, lim))
        })
    }

    fn planes_of(x: &CMatrix) -> CPlanes<i64> {
        let (re, im) = to_planes(x);
        CPlanes::new(re, im).unwrap()
    }

    #[test]
    fn blocked_cpm3_matches_direct_across_shapes() {
        forall(
            0xC93,
            40,
            |rng, size| {
                let m = rng.usize_in(1, size.max(1).min(9));
                let n = rng.usize_in(1, size.max(1).min(9));
                let p = rng.usize_in(1, size.max(1).min(9));
                (random_c(rng, m, n, 300), random_c(rng, n, p, 300))
            },
            |(x, y)| {
                let want = planes_of(&cmatmul_direct(x, y).0);
                for threads in [1usize, 4] {
                    let (got, _) =
                        cmatmul_cpm3_blocked(&planes_of(x), &planes_of(y), &tiny_cfg(threads))
                            .unwrap();
                    if got != want {
                        return Err(format!(
                            "CPM3 lowering diverged at {}x{}x{} threads={threads}",
                            x.rows, x.cols, y.cols
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn ledger_squares_match_reference_cpm3() {
        // the three passes must spend exactly the §9 square budget the
        // reference CPM3 ledgers: 3·(MNP + MN + NP)
        let mut rng = Rng::new(0xC94);
        for (m, n, p) in [(1usize, 1usize, 1usize), (4, 6, 3), (8, 8, 8)] {
            let x = random_c(&mut rng, m, n, 100);
            let y = random_c(&mut rng, n, p, 100);
            let (_, reference) = cmatmul_cpm3(&x, &y);
            let (_, blocked) =
                cmatmul_cpm3_blocked(&planes_of(&x), &planes_of(&y), &tiny_cfg(1)).unwrap();
            assert_eq!(blocked.squares, reference.squares, "{m}x{n}x{p}");
            assert_eq!(blocked.mults, 0);
            assert_eq!(blocked, cpm3_blocked_ledger(m, n, p));
        }
    }

    #[test]
    fn ledger_equals_per_element_counting_of_the_three_passes() {
        fn lowered_ref(m: usize, n: usize, p: usize) -> OpCounts {
            let mut ops = OpCounts::ZERO;
            for _ in 0..m * n {
                ops.add(); // forming A+B
            }
            for _ in 0..2 * n * p {
                ops.add(); // forming C+S and S−C
            }
            for _pass in 0..3 {
                for _ in 0..m * n {
                    ops.square(); // row corrections
                    ops.add();
                }
                for _ in 0..n * p {
                    ops.square(); // column corrections
                    ops.add();
                }
                for _out in 0..m * p {
                    ops.add(); // correction seed
                    for _k in 0..n {
                        ops.square();
                        ops.add_n(2);
                    }
                    ops.shift();
                }
            }
            for _ in 0..2 * m * p {
                ops.add(); // Z_re = M1 − M2, Z_im = M1 + M3
            }
            ops
        }
        for (m, n, p) in [(1usize, 1usize, 1usize), (2, 5, 3), (7, 4, 6)] {
            assert_eq!(cpm3_blocked_ledger(m, n, p), lowered_ref(m, n, p), "{m}x{n}x{p}");
        }
    }

    #[test]
    fn prepared_amortises_the_y_side() {
        let mut rng = Rng::new(0xC95);
        let x = random_c(&mut rng, 5, 7, 80);
        let y = random_c(&mut rng, 7, 4, 80);
        let (full, full_ops) =
            cmatmul_cpm3_blocked(&planes_of(&x), &planes_of(&y), &tiny_cfg(1)).unwrap();
        let (prep, prep_ops) = PreparedCpm3::new(&planes_of(&y)).unwrap();
        assert_eq!(prep.in_features(), 7);
        assert_eq!(prep.out_features(), 4);
        let (amortised, call_ops) = prep.mul(&planes_of(&x), &tiny_cfg(2)).unwrap();
        assert_eq!(amortised, full);
        assert_eq!(call_ops, cpm3_prepared_ledger(5, 7, 4));
        assert_eq!(call_ops + prep_ops, full_ops, "§3 amortisation must be exact");
        // the cached planes round-trip to the original Y
        let (yre, yim) = to_planes(&y);
        assert_eq!(prep.re_plane(), &yre);
        assert_eq!(prep.im_plane(), yim);
    }

    #[test]
    fn mul_into_matches_mul_and_stops_allocating() {
        let mut rng = Rng::new(0xC97);
        let x = random_c(&mut rng, 6, 8, 70);
        let y = random_c(&mut rng, 8, 5, 70);
        let (prep, _) = PreparedCpm3::new(&planes_of(&y)).unwrap();
        let (want, want_ops) = prep.mul(&planes_of(&x), &tiny_cfg(1)).unwrap();

        let mut ws = EngineWorkspace::new();
        let (mut z_re, mut z_im) = (Vec::new(), Vec::new());
        for round in 0..3 {
            let ops = prep
                .mul_into(&planes_of(&x), &tiny_cfg(1), &mut ws, &mut z_re, &mut z_im)
                .unwrap();
            assert_eq!(z_re, want.re.data(), "round {round}");
            assert_eq!(z_im, want.im.data(), "round {round}");
            assert_eq!(ops, want_ops);
        }
        // seven checkouts per call (A+B, 3 corrections, 3 pass planes):
        // only the first call may touch the allocator
        assert_eq!(ws.checkouts(), 21);
        assert_eq!(ws.grows(), 7, "steady state must reuse retained planes");
        // shape errors surface before any scratch is disturbed
        assert!(matches!(
            prep.mul_into(
                &CPlanes::<i64>::zeros(2, 3),
                &tiny_cfg(1),
                &mut ws,
                &mut z_re,
                &mut z_im
            )
            .unwrap_err(),
            LinalgError::ContractionMismatch { .. }
        ));
    }

    #[test]
    fn blocked_cpm_twin_matches_direct_and_spends_the_eq20_budget() {
        forall(
            0xC98,
            30,
            |rng, size| {
                let m = rng.usize_in(1, size.max(1).min(8));
                let n = rng.usize_in(1, size.max(1).min(8));
                let p = rng.usize_in(1, size.max(1).min(8));
                (random_c(rng, m, n, 200), random_c(rng, n, p, 200))
            },
            |(x, y)| {
                let want = planes_of(&cmatmul_direct(x, y).0);
                for threads in [1usize, 4] {
                    let (got, ops) =
                        cmatmul_cpm_blocked(&planes_of(x), &planes_of(y), &tiny_cfg(threads))
                            .unwrap();
                    if got != want {
                        return Err(format!(
                            "CPM twin diverged at {}x{}x{} threads={threads}",
                            x.rows, x.cols, y.cols
                        ));
                    }
                    if ops != cpm_blocked_ledger(x.rows, x.cols, y.cols) {
                        return Err("CPM twin ledger diverged from its formula".into());
                    }
                    // the §6 square budget: identical to the reference CPM
                    if ops.squares != cmatmul_cpm(x, y).1.squares || ops.mults != 0 {
                        return Err("CPM twin square budget diverged from eq. 20".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prepared_cpm_amortises_the_y_side() {
        let mut rng = Rng::new(0xC99);
        let x = random_c(&mut rng, 4, 6, 60);
        let y = random_c(&mut rng, 6, 3, 60);
        let (full, full_ops) =
            cmatmul_cpm_blocked(&planes_of(&x), &planes_of(&y), &tiny_cfg(1)).unwrap();
        let (prep, prep_ops) = PreparedCpm::new(&planes_of(&y)).unwrap();
        assert_eq!(prep.in_features(), 6);
        assert_eq!(prep.out_features(), 3);
        assert_eq!(prep_ops.squares, 2 * 6 * 3);
        let (amortised, call_ops) = prep.mul(&planes_of(&x), &tiny_cfg(2)).unwrap();
        assert_eq!(amortised, full);
        assert_eq!(call_ops, cpm_prepared_ledger(4, 6, 3));
        assert_eq!(call_ops + prep_ops, full_ops, "§3 amortisation must be exact");
        // and the 3-square lowering beats the 4-square twin on squares —
        // the §6 vs §9 comparison the ratio bench reports
        let (_, cpm3_ops) =
            cmatmul_cpm3_blocked(&planes_of(&x), &planes_of(&y), &tiny_cfg(1)).unwrap();
        assert!(cpm3_ops.squares < full_ops.squares);
    }

    #[test]
    fn cconv1d_lowering_matches_the_reference_convolutions() {
        use super::super::super::conv::{cconv1d_cpm3, cconv1d_direct};

        forall(
            0xC9A,
            30,
            |rng, size| {
                let n = rng.usize_in(1, size.max(1).min(10));
                let l = n + rng.usize_in(0, 30);
                let c = |rng: &mut Rng, len: usize| -> Vec<Complex<i64>> {
                    (0..len)
                        .map(|_| Complex::new(rng.i64_in(-200, 200), rng.i64_in(-200, 200)))
                        .collect()
                };
                (c(rng, n), c(rng, l))
            },
            |(w, x)| {
                let (want, _) = cconv1d_direct(w, x);
                let split = |v: &[Complex<i64>]| -> (Vec<i64>, Vec<i64>) {
                    (v.iter().map(|c| c.re).collect(), v.iter().map(|c| c.im).collect())
                };
                let (wre, wim) = split(w);
                let (xre, xim) = split(x);
                let (n, l) = (w.len(), x.len());
                let k = l - n + 1;
                for threads in [1usize, 4] {
                    let (re, im, ops) =
                        cconv1d_cpm3_blocked(&wre, &wim, &xre, &xim, &tiny_cfg(threads))
                            .unwrap();
                    for (i, zw) in want.iter().enumerate() {
                        if re[i] != zw.re || im[i] != zw.im {
                            return Err(format!(
                                "cconv1d lowering diverged at n={n} l={l} k={i} \
                                 threads={threads}"
                            ));
                        }
                    }
                    if ops != cpm3_blocked_ledger(k, n, 1) {
                        return Err("cconv1d lowering ledger diverged from formula".into());
                    }
                    if ops.mults != 0 {
                        return Err("cconv1d lowering performed a general mult".into());
                    }
                    // sanity vs the streaming reference: both are pure
                    // square budgets over the same window count
                    let (_, stream) = cconv1d_cpm3(w, x);
                    if stream.mults != 0 {
                        return Err("reference cconv1d_cpm3 ledger contaminated".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prepared_cconv1d_amortises_the_kernel() {
        let mut rng = Rng::new(0xC9B);
        let n = 6usize;
        let l = 40usize;
        let wre = rng.vec_i64(n, -90, 90);
        let wim = rng.vec_i64(n, -90, 90);
        let xre = rng.vec_i64(l, -90, 90);
        let xim = rng.vec_i64(l, -90, 90);
        let k = l - n + 1;
        let (full_re, full_im, full_ops) =
            cconv1d_cpm3_blocked(&wre, &wim, &xre, &xim, &tiny_cfg(1)).unwrap();
        let (prep, prep_ops) = PreparedCpm3Conv1d::new(&wre, &wim).unwrap();
        assert_eq!(prep.taps(), n);
        assert_eq!(prep_ops.squares, (3 * n) as u64);
        let (re, im, call_ops) = prep.apply(&xre, &xim, &tiny_cfg(2)).unwrap();
        assert_eq!(re, full_re);
        assert_eq!(im, full_im);
        assert_eq!(call_ops, cpm3_prepared_ledger(k, n, 1));
        assert_eq!(call_ops + prep_ops, full_ops, "kernel amortisation must be exact");

        // typed errors for malformed signals/kernels
        assert_eq!(
            PreparedCpm3Conv1d::<i64>::new(&[], &[]).unwrap_err(),
            LinalgError::EmptyInput { what: "kernel" }
        );
        assert!(matches!(
            PreparedCpm3Conv1d::new(&[1i64, 2], &[3]).unwrap_err(),
            LinalgError::ShapeMismatch { .. }
        ));
        assert!(matches!(
            prep.apply(&[1i64, 2], &[1, 2], &tiny_cfg(1)).unwrap_err(),
            LinalgError::KernelDoesNotFit { kh: 1, in_h: 1, .. }
        ));
        assert!(matches!(
            prep.apply(&[1i64; 9], &[1; 8], &tiny_cfg(1)).unwrap_err(),
            LinalgError::ShapeMismatch { .. }
        ));
    }

    #[test]
    fn f32_planes_are_exact_on_integer_data() {
        // integer-valued f32 planes keep every intermediate below 2^24,
        // so the float lowering must agree exactly with the i64 result
        let mut rng = Rng::new(0xC96);
        let x = random_c(&mut rng, 6, 9, 40);
        let y = random_c(&mut rng, 9, 5, 40);
        let want = planes_of(&cmatmul_direct(&x, &y).0);
        let to_f32 = |p: &CPlanes<i64>| CPlanes {
            re: p.re.map(|v| v as f32),
            im: p.im.map(|v| v as f32),
        };
        let (got, _) =
            cmatmul_cpm3_blocked(&to_f32(&planes_of(&x)), &to_f32(&planes_of(&y)), &tiny_cfg(2))
                .unwrap();
        for (g, w) in got.re.data().iter().zip(want.re.data()) {
            assert_eq!(*g as i64, *w);
        }
        for (g, w) in got.im.data().iter().zip(want.im.data()) {
            assert_eq!(*g as i64, *w);
        }
    }

    #[test]
    fn shape_errors_are_typed() {
        let x = CPlanes::<i64>::zeros(2, 3);
        let y = CPlanes::<i64>::zeros(4, 2);
        assert_eq!(
            cmatmul_cpm3_blocked(&x, &y, &EngineConfig::default()).unwrap_err(),
            LinalgError::ContractionMismatch { left_cols: 3, right_rows: 4 }
        );
        assert!(matches!(
            CPlanes::new(Matrix::<i64>::zeros(2, 2), Matrix::<i64>::zeros(3, 2)).unwrap_err(),
            LinalgError::ShapeMismatch { .. }
        ));
        // a hand-built mismatched pair (the fields are public) must also
        // surface as a typed error, not a plane_add panic
        let bad = CPlanes { re: Matrix::<i64>::zeros(2, 3), im: Matrix::<i64>::zeros(2, 4) };
        let ok = CPlanes::<i64>::zeros(3, 2);
        assert!(matches!(
            cmatmul_cpm3_blocked(&bad, &ok, &EngineConfig::default()).unwrap_err(),
            LinalgError::ShapeMismatch { .. }
        ));
    }
}
