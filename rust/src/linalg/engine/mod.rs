//! The blocked, multi-threaded square-kernel engine — the serving hot path.
//!
//! The reference stack in [`super::matmul`]/[`super::conv`]/[`super::complex`]
//! exists to make the paper's op-count claims *auditable*; this module makes
//! the square-based kernels *fast in software* so the claims survive contact
//! with production traffic:
//!
//! * [`kernels`] — flat row-slice inner loops (`acc[j] += (s + b[j])²` and
//!   friends, including the CPM/CPM3 complex forms). Every hot loop in the
//!   reference stack delegates here, so there is exactly one place the
//!   compiler must vectorise.
//! * [`blocked`] — cache-blocked (tiled) square-based matmul over any
//!   [`SquareScalar`] (`i64` bit-exact, `f32`/`f64` for float serving), plus
//!   [`PreparedB`], the precomputed-correction cache for constant weights:
//!   the paper's §3 inference case, where `Sb_j = −Σ_k b_kj²` is computed
//!   once per model and amortised across every request.
//!   [`PreparedB::new_shared`] wraps the cache in an `Arc` so a sharded
//!   serving pool pays that one-time cost once for *all* its workers.
//! * [`threaded`] — a row-partitioned parallel driver on
//!   `std::thread::scope` (no dependencies): output rows are split into
//!   contiguous chunks, one scoped thread per chunk, no locks because the
//!   chunks are disjoint `&mut` slices.
//!
//! * [`im2col`]/[`conv`] — the convolution lowering: patch extraction plus
//!   [`PreparedConvBank`], so a fixed CNN filter bank runs as one blocked
//!   square matmul per image (or per batch) with its §3 corrections paid
//!   once per model.
//! * [`complex`] — the CPM3 lowering: plane-split complex matmul as three
//!   blocked square passes ([`CPlanes`], [`PreparedCpm3`]), spending
//!   exactly the §9 square budget.
//!
//! Ledgers are *hoisted*: an [`OpCounts`](super::OpCounts) is a
//! deterministic function of the shape (asserted equal to per-element
//! counting by the tests), so the engine spends zero instructions on
//! bookkeeping inside the inner loops.
//!
//! The serving integration lives in `coordinator::native`: a
//! [`BatchExecutor`](crate::coordinator::BatchExecutor) backed by these
//! kernels, so the inference server can serve square-based models without
//! the PJRT runtime.

pub mod blocked;
pub mod complex;
pub mod conv;
pub mod im2col;
pub mod kernels;
pub mod threaded;

pub use blocked::{
    col_corrections_flat, effective_threads, matmul_direct_blocked,
    matmul_square_blocked, matmul_square_naive, matmul_square_prepared,
    row_corrections_flat, square_matmul_const_b_ledger, square_matmul_ledger,
    EngineConfig, PreparedB,
};
pub use complex::{
    cmatmul_cpm3_blocked, cpm3_blocked_ledger, cpm3_prepared_ledger, plane_add,
    plane_sub, CPlanes, PreparedCpm3,
};
pub use conv::{conv2d_square_blocked, PreparedConvBank};
pub use im2col::{bank_matrix, im2col, im2col_stacked, scatter_bank_output};
pub use threaded::max_threads;

use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// Scalar the square-kernel engine runs on.
///
/// `i64` is the bit-exact hardware domain (the trailing ÷2 of eq. 4 is an
/// arithmetic shift — exact because the sum is always even); `f32`/`f64`
/// are the float serving domain (÷2 is an exact ×0.5).
pub trait SquareScalar:
    Copy
    + Default
    + Send
    + Sync
    + PartialEq
    + std::fmt::Debug
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + 'static
{
    /// The exact ÷2 recovering eq. (4) from the partial-multiplication sum.
    fn halve(self) -> Self;
}

impl SquareScalar for i64 {
    #[inline(always)]
    fn halve(self) -> Self {
        self >> 1
    }
}

impl SquareScalar for f32 {
    #[inline(always)]
    fn halve(self) -> Self {
        0.5 * self
    }
}

impl SquareScalar for f64 {
    #[inline(always)]
    fn halve(self) -> Self {
        0.5 * self
    }
}
