//! The blocked, multi-threaded square-kernel engine — the serving hot path.
//!
//! The reference stack in [`super::matmul`]/[`super::conv`]/[`super::complex`]
//! exists to make the paper's op-count claims *auditable*; this module makes
//! the square-based kernels *fast in software* so the claims survive contact
//! with production traffic:
//!
//! * [`kernels`] — flat row-slice inner loops (`acc[j] += (s + b[j])²` and
//!   friends, including the CPM/CPM3 complex forms). Every hot loop in the
//!   reference stack delegates here, so there is exactly one place the
//!   compiler must vectorise.
//! * [`blocked`] — cache-blocked (tiled) square-based matmul over any
//!   [`SquareScalar`] (`i64` bit-exact, `f32`/`f64` for float serving), plus
//!   [`PreparedB`], the precomputed-correction cache for constant weights:
//!   the paper's §3 inference case, where `Sb_j = −Σ_k b_kj²` is computed
//!   once per model and amortised across every request.
//!   [`PreparedB::new_shared`] wraps the cache in an `Arc` so a sharded
//!   serving pool pays that one-time cost once for *all* its workers.
//! * [`threaded`] — a row-partitioned parallel driver on
//!   `std::thread::scope` (no dependencies): output rows are split into
//!   contiguous chunks, one scoped thread per chunk, no locks because the
//!   chunks are disjoint `&mut` slices.
//!
//! * [`spec`]/[`im2col`]/[`conv`] — the generalized convolution
//!   subsystem: [`ConvSpec`] names any NCHW multi-channel / strided /
//!   padded / dilated geometry once and validates it once; the NCHW
//!   patch extraction absorbs all of it, so every spec lowers to the
//!   same `(K, C·kh·kw, F)` square matmul; [`PreparedConvBank`] pays a
//!   fixed CNN filter bank's §3 corrections once per model (or pool).
//! * [`workspace`] — [`EngineWorkspace`], the buffer arena behind the
//!   allocation-free steady state: patch matrices, GEMM outputs,
//!   corrections and CPM3 scratch planes are checked out per batch and
//!   returned, so a warmed serving worker performs zero heap
//!   allocations per batch (single-threaded engine config; the scoped
//!   threaded driver allocates per spawn).
//! * [`complex`] — the CPM3 lowering: plane-split complex matmul as three
//!   blocked square passes ([`CPlanes`], [`PreparedCpm3`]), spending
//!   exactly the §9 square budget — plus the 4-square CPM twin
//!   ([`PreparedCpm`]) for the §6 comparison and the 1-D correlation
//!   lowering ([`PreparedCpm3Conv1d`]).
//!
//! Ledgers are *hoisted*: an [`OpCounts`](super::OpCounts) is a
//! deterministic function of the shape (asserted equal to per-element
//! counting by the tests), so the engine spends zero instructions on
//! bookkeeping inside the inner loops.
//!
//! The serving integration lives in `coordinator::native`: a
//! [`BatchExecutor`](crate::coordinator::BatchExecutor) backed by these
//! kernels, so the inference server can serve square-based models without
//! the PJRT runtime.

pub mod blocked;
pub mod complex;
pub mod conv;
pub mod im2col;
pub mod kernels;
pub mod spec;
pub mod threaded;
pub mod workspace;

pub use blocked::{
    col_corrections_flat, effective_threads, matmul_direct_blocked,
    matmul_direct_blocked_into, matmul_square_blocked, matmul_square_naive,
    matmul_square_prepared, matmul_square_prepared_into,
    matmul_square_prepared_tile_into, matmul_square_tile_into,
    row_corrections_flat, row_corrections_into, row_corrections_ledger,
    square_matmul_const_b_ledger, square_matmul_ledger,
    square_matmul_tile_ledger, EngineConfig, PreparedB,
};
pub use complex::{
    cconv1d_cpm3_blocked, cmatmul_cpm3_blocked, cmatmul_cpm_blocked,
    cpm3_blocked_ledger, cpm3_prepared_ledger, cpm_blocked_ledger,
    cpm_prepared_ledger, plane_add, plane_sub, CPlanes, PreparedCpm,
    PreparedCpm3, PreparedCpm3Conv1d,
};
pub use conv::{conv2d_square_blocked, PreparedConvBank};
pub use im2col::{
    bank_matrix, im2col, im2col_nchw, im2col_nchw_into, im2col_stacked,
    nchw_bank_matrix, scatter_bank_output, scatter_bank_output_into,
};
pub use spec::ConvSpec;
pub use threaded::max_threads;
pub use workspace::EngineWorkspace;

use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// Scalar the square-kernel engine runs on.
///
/// `i64` is the bit-exact hardware domain (the trailing ÷2 of eq. 4 is an
/// arithmetic shift — exact because the sum is always even); `f32`/`f64`
/// are the float serving domain (÷2 is an exact ×0.5).
pub trait SquareScalar:
    Copy
    + Default
    + Send
    + Sync
    + PartialEq
    + std::fmt::Debug
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + 'static
{
    /// The exact ÷2 recovering eq. (4) from the partial-multiplication sum.
    fn halve(self) -> Self;
}

impl SquareScalar for i64 {
    #[inline(always)]
    fn halve(self) -> Self {
        self >> 1
    }
}

impl SquareScalar for f32 {
    #[inline(always)]
    fn halve(self) -> Self {
        0.5 * self
    }
}

impl SquareScalar for f64 {
    #[inline(always)]
    fn halve(self) -> Self {
        0.5 * self
    }
}
