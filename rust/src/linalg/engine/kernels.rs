//! Flat row-slice inner loops — the one place the hot arithmetic lives.
//!
//! Every kernel walks contiguous slices with no index arithmetic beyond the
//! zip, so the compiler can unroll/vectorise, and carries **no** ledger
//! bookkeeping: callers hoist their [`OpCounts`](crate::linalg::OpCounts)
//! as a function of the shape (the tests in `linalg::conv`/`linalg::complex`
//! assert the hoisted ledgers equal per-element counting).

use crate::arith::complex::Complex;

use super::SquareScalar;

/// Square-accumulate one row: `acc[j] += (s + b[j])²` — the eq. (4) window
/// term for one `(i, k)` pair spread across a row of C.
#[inline(always)]
pub fn sq_acc_row<T: SquareScalar>(acc: &mut [T], s: T, b: &[T]) {
    debug_assert_eq!(acc.len(), b.len());
    for (c, &bv) in acc.iter_mut().zip(b) {
        let t = s + bv;
        *c += t * t;
    }
}

/// Square-accumulate with a shared-energy correction:
/// `acc[j] += (s + x[j])² − x2[j]` — the eq. (11)/(13) convolution window
/// term, where `x2` is the per-sample square shared across windows.
#[inline(always)]
pub fn sq_sub_acc_row<T: SquareScalar>(acc: &mut [T], s: T, x: &[T], x2: &[T]) {
    debug_assert_eq!(acc.len(), x.len());
    debug_assert_eq!(acc.len(), x2.len());
    for ((c, &xv), &ev) in acc.iter_mut().zip(x).zip(x2) {
        let t = s + xv;
        *c += t * t - ev;
    }
}

/// Multiply-accumulate one row: `acc[j] += a · b[j]` — the direct (eq. 3)
/// baseline in the same row-sliced form.
#[inline(always)]
pub fn mul_acc_row<T: SquareScalar>(acc: &mut [T], a: T, b: &[T]) {
    debug_assert_eq!(acc.len(), b.len());
    for (c, &bv) in acc.iter_mut().zip(b) {
        *c += a * bv;
    }
}

/// Direct complex multiply-accumulate row: `z[k] += x · y[k]` (eq. 16,
/// 4 real mults per element).
#[inline(always)]
pub fn cmul_acc_crow(z: &mut [Complex<i64>], x: Complex<i64>, y: &[Complex<i64>]) {
    debug_assert_eq!(z.len(), y.len());
    let (a, b) = (x.re, x.im);
    for (zv, &yv) in z.iter_mut().zip(y) {
        let (c, s) = (yv.re, yv.im);
        zv.re += a * c - b * s;
        zv.im += b * c + a * s;
    }
}

/// 3-real-mult complex multiply-accumulate row (eq. 31, Karatsuba-style).
#[inline(always)]
pub fn cmul3_acc_crow(z: &mut [Complex<i64>], x: Complex<i64>, y: &[Complex<i64>]) {
    debug_assert_eq!(z.len(), y.len());
    let (a, b) = (x.re, x.im);
    for (zv, &yv) in z.iter_mut().zip(y) {
        let (c, s) = (yv.re, yv.im);
        let shared = c * (a + b);
        zv.re += shared - b * (c + s);
        zv.im += a * (s - c) + shared;
    }
}

/// CPM (4-square) partial-multiplication accumulate row (eq. 17–19):
/// `z[k].re += (a+c)² + (b−s)²`, `z[k].im += (b+c)² + (a+s)²`.
#[inline(always)]
pub fn cpm_acc_crow(z: &mut [Complex<i64>], x: Complex<i64>, y: &[Complex<i64>]) {
    debug_assert_eq!(z.len(), y.len());
    let (a, b) = (x.re, x.im);
    for (zv, &yv) in z.iter_mut().zip(y) {
        let (c, s) = (yv.re, yv.im);
        let t1 = a + c;
        let t2 = b - s;
        let t3 = b + c;
        let t4 = a + s;
        zv.re += t1 * t1 + t2 * t2;
        zv.im += t3 * t3 + t4 * t4;
    }
}

/// CPM3 (3-square) partial-multiplication accumulate row (eq. 32–35): the
/// `(c+a+b)²` square is computed once and feeds both accumulators.
#[inline(always)]
pub fn cpm3_acc_crow(z: &mut [Complex<i64>], x: Complex<i64>, y: &[Complex<i64>]) {
    debug_assert_eq!(z.len(), y.len());
    let (a, b) = (x.re, x.im);
    for (zv, &yv) in z.iter_mut().zip(y) {
        let (c, s) = (yv.re, yv.im);
        let t = c + a + b;
        let t = t * t;
        let u = b + c + s;
        let v = a + s - c;
        zv.re += t - u * u;
        zv.im += t + v * v;
    }
}

/// CPM convolution window accumulate (eq. 28/29): one tap `w` against a
/// run of samples, planar accumulators, per-sample energy `e[j] = x²+y²`
/// shared across windows: `re[j] += (c+x)² + (s−y)²... − e[j]` per eq. 28.
#[inline(always)]
pub fn cpm_conv_acc_rows(
    re: &mut [i64],
    im: &mut [i64],
    w: Complex<i64>,
    x: &[Complex<i64>],
    e: &[i64],
) {
    debug_assert_eq!(re.len(), im.len());
    debug_assert_eq!(re.len(), x.len());
    debug_assert_eq!(re.len(), e.len());
    let (c, s) = (w.re, w.im);
    for (((rv, iv), &xv), &ev) in re.iter_mut().zip(im.iter_mut()).zip(x).zip(e) {
        let t1 = c + xv.re;
        let t2 = s - xv.im;
        let t3 = s + xv.re;
        let t4 = c + xv.im;
        *rv += t1 * t1 + t2 * t2 - ev;
        *iv += t3 * t3 + t4 * t4 - ev;
    }
}

/// CPM3 convolution window accumulate (eq. 45/46): one tap `w` against a
/// run of samples with the shared per-sample common terms `com_re`/`com_im`
/// (3 squares per sample, shared across every window).
#[inline(always)]
pub fn cpm3_conv_acc_rows(
    re: &mut [i64],
    im: &mut [i64],
    w: Complex<i64>,
    x: &[Complex<i64>],
    com_re: &[i64],
    com_im: &[i64],
) {
    debug_assert_eq!(re.len(), im.len());
    debug_assert_eq!(re.len(), x.len());
    debug_assert_eq!(re.len(), com_re.len());
    debug_assert_eq!(re.len(), com_im.len());
    let (c, s) = (w.re, w.im);
    for ((((rv, iv), &xv), &cr), &ci) in re
        .iter_mut()
        .zip(im.iter_mut())
        .zip(x)
        .zip(com_re)
        .zip(com_im)
    {
        let t = c + xv.re + xv.im;
        let t = t * t;
        let u = xv.im + c + s;
        let v = xv.re + s - c;
        *rv += t - u * u + cr;
        *iv += t + v * v + ci;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::complex::{cmul_direct, cpm, cpm3};
    use crate::testkit::Rng;

    #[test]
    fn sq_acc_row_matches_scalar() {
        let mut rng = Rng::new(1);
        let b = rng.vec_i64(17, -100, 100);
        let s = rng.i64_in(-100, 100);
        let mut acc = rng.vec_i64(17, -100, 100);
        let want: Vec<i64> = acc.iter().zip(&b).map(|(&a, &bv)| a + (s + bv) * (s + bv)).collect();
        sq_acc_row(&mut acc, s, &b);
        assert_eq!(acc, want);
    }

    #[test]
    fn sq_sub_acc_row_matches_scalar() {
        let mut rng = Rng::new(2);
        let x = rng.vec_i64(11, -50, 50);
        let x2: Vec<i64> = x.iter().map(|&v| v * v).collect();
        let s = 7;
        let mut acc = vec![0i64; 11];
        sq_sub_acc_row(&mut acc, s, &x, &x2);
        for (a, &xv) in acc.iter().zip(&x) {
            assert_eq!(*a, (s + xv) * (s + xv) - xv * xv);
        }
    }

    #[test]
    fn complex_rows_match_scalar_cpms() {
        let mut rng = Rng::new(3);
        let rc = |rng: &mut Rng| Complex::new(rng.i64_in(-99, 99), rng.i64_in(-99, 99));
        let x = rc(&mut rng);
        let y: Vec<Complex<i64>> = (0..9).map(|_| rc(&mut rng)).collect();

        let mut z = vec![Complex::ZERO; 9];
        cpm_acc_crow(&mut z, x, &y);
        for (zv, &yv) in z.iter().zip(&y) {
            assert_eq!(*zv, cpm(x, yv));
        }

        let mut z = vec![Complex::ZERO; 9];
        cpm3_acc_crow(&mut z, x, &y);
        for (zv, &yv) in z.iter().zip(&y) {
            assert_eq!(*zv, cpm3(x, yv));
        }

        let mut z = vec![Complex::ZERO; 9];
        cmul_acc_crow(&mut z, x, &y);
        let mut z3 = vec![Complex::ZERO; 9];
        cmul3_acc_crow(&mut z3, x, &y);
        for ((zv, z3v), &yv) in z.iter().zip(&z3).zip(&y) {
            assert_eq!(*zv, cmul_direct(x, yv));
            assert_eq!(z3v, zv);
        }
    }
}
