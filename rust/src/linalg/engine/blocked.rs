//! Cache-blocked square-based matmul with precomputed-correction caching.
//!
//! The compute is eq. (4): `C = ½(Sab + Sa·1ᵀ + 1·Sbᵀ)` with
//! `Sab_ij = Σ_k (a_ik + b_kj)²`. The engine tiles the k and j loops so a
//! `block_k × block_n` panel of B stays cache-resident while every output
//! row in the partition streams over it, seeds each output row with the
//! rank-1 corrections (the Fig. 1b register protocol), and finishes with
//! the exact ÷2. Ledgers are hoisted — deterministic in the shape — so the
//! inner loops carry no bookkeeping.

use super::super::counts::OpCounts;
use super::super::matrix::Matrix;
use super::workspace::EngineWorkspace;
use super::{kernels, threaded, SquareScalar};

/// Tiling / parallelism knobs for the engine.
///
/// Defaults suit the CI machine: 64 k-steps × 512 output columns of `i64`
/// is a 256 KiB B-panel (fits L2) and the C-row slice stays in L1.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// contraction-dimension tile (rows of B per panel)
    pub block_k: usize,
    /// output-column tile (columns of B/C per panel)
    pub block_n: usize,
    /// worker threads for the row-partitioned driver; 1 = single-threaded
    pub threads: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { block_k: 64, block_n: 512, threads: 1 }
    }
}

impl EngineConfig {
    /// Default blocking with one worker per available core.
    pub fn threaded() -> Self {
        Self { threads: threaded::max_threads(), ..Self::default() }
    }

    /// Default blocking with an explicit thread count.
    pub fn with_threads(threads: usize) -> Self {
        Self { threads: threads.max(1), ..Self::default() }
    }
}

/// Row corrections `Sa_i = −Σ_k a_ik²` over contiguous row slices.
pub fn row_corrections_flat<T: SquareScalar>(a: &Matrix<T>) -> Vec<T> {
    (0..a.rows)
        .map(|i| {
            let mut acc = T::default();
            for &v in a.row(i) {
                acc += v * v;
            }
            -acc
        })
        .collect()
}

/// Row corrections written into a caller-provided buffer — the workspace
/// path of [`row_corrections_flat`]: same values, zero allocations.
pub fn row_corrections_into<T: SquareScalar>(a: &Matrix<T>, sa: &mut [T]) {
    assert_eq!(
        sa.len(),
        a.rows,
        "row_corrections_into: buffer must hold one correction per row"
    );
    for (i, out) in sa.iter_mut().enumerate() {
        let mut acc = T::default();
        for &v in a.row(i) {
            acc += v * v;
        }
        *out = -acc;
    }
}

/// Column corrections `Sb_j = −Σ_k b_kj²`, accumulated row-sweep so the
/// access pattern stays contiguous (no strided column walks).
pub fn col_corrections_flat<T: SquareScalar>(b: &Matrix<T>) -> Vec<T> {
    let mut sb = vec![T::default(); b.cols];
    for k in 0..b.rows {
        for (s, &v) in sb.iter_mut().zip(b.row(k)) {
            *s += v * v;
        }
    }
    for s in sb.iter_mut() {
        *s = -*s;
    }
    sb
}

/// Hoisted ledger of the full square-based matmul (corrections included):
/// `M·N·P + M·N + N·P` squares, zero general multiplications — eq. (5)/(6).
pub fn square_matmul_ledger(m: usize, n: usize, p: usize) -> OpCounts {
    let (m, n, p) = (m as u64, n as u64, p as u64);
    OpCounts {
        mults: 0,
        squares: m * n * p + m * n + n * p,
        adds: m * n + n * p + 2 * m * n * p + m * p,
        shifts: m * p,
    }
}

/// Hoisted ledger of the constant-B case (§3 inference): the `N·P`
/// correction squares are amortised away, leaving `M·N·P + M·N`.
pub fn square_matmul_const_b_ledger(m: usize, n: usize, p: usize) -> OpCounts {
    let (m, n, p) = (m as u64, n as u64, p as u64);
    OpCounts {
        mults: 0,
        squares: m * n * p + m * n,
        adds: m * n + m * p + 2 * m * n * p,
        shifts: m * p,
    }
}

/// The two-level tile sweep shared by every kernel flavour: for each
/// `block_k × block_n` panel of B, every row of the partition `[i0, i1)`
/// streams over it through `kernel(c_slice, a_ik, b_row_slice)`.
fn tile_sweep<T: SquareScalar>(
    c_rows: &mut [T],
    i0: usize,
    i1: usize,
    a: &Matrix<T>,
    b: &Matrix<T>,
    cfg: &EngineConfig,
    kernel: impl Fn(&mut [T], T, &[T]),
) {
    let n = a.cols;
    let p = b.cols;
    debug_assert_eq!(c_rows.len(), (i1 - i0) * p);
    let bk = cfg.block_k.max(1);
    let bn = cfg.block_n.max(1);
    let mut kc = 0;
    while kc < n {
        let k_end = (kc + bk).min(n);
        let mut jc = 0;
        while jc < p {
            let j_end = (jc + bn).min(p);
            for ri in 0..(i1 - i0) {
                let a_row = a.row(i0 + ri);
                let c_row = &mut c_rows[ri * p + jc..ri * p + j_end];
                for k in kc..k_end {
                    kernel(c_row, a_row[k], &b.row(k)[jc..j_end]);
                }
            }
            jc = j_end;
        }
        kc = k_end;
    }
}

/// The tiled square core over a contiguous row partition `[i0, i1)` of C.
/// `c_rows` is exactly that partition's row-major storage.
fn block_rows_into<T: SquareScalar>(
    c_rows: &mut [T],
    i0: usize,
    i1: usize,
    a: &Matrix<T>,
    b: &Matrix<T>,
    sa: &[T],
    sb: &[T],
    cfg: &EngineConfig,
) {
    let p = b.cols;

    // seed each output row with the rank-1 corrections
    for ri in 0..(i1 - i0) {
        let sai = sa[i0 + ri];
        for (cv, &sbj) in c_rows[ri * p..(ri + 1) * p].iter_mut().zip(sb) {
            *cv = sai + sbj;
        }
    }

    // tiled i-k-j accumulation of the (a+b)² window terms
    tile_sweep(c_rows, i0, i1, a, b, cfg, kernels::sq_acc_row);

    // the trailing exact ÷2 of eq. (4)
    for v in c_rows.iter_mut() {
        *v = v.halve();
    }
}

/// Threads actually worth spawning for `m·n·p` useful operations:
/// `std::thread::scope` creates and joins OS threads per call, which only
/// pays off once each worker gets a substantial slice. Below the
/// threshold the work degrades gracefully toward single-threaded. Public
/// so callers (CLI banners, capacity planning) can report the real
/// parallelism a shape will get rather than the requested knob.
pub fn effective_threads(cfg_threads: usize, m: usize, n: usize, p: usize) -> usize {
    // ≈128k inner-loop ops (~100 µs) per additional thread
    const MIN_WORK_PER_THREAD: usize = 1 << 17;
    let work = m.saturating_mul(n).saturating_mul(p);
    cfg_threads
        .max(1)
        .min(m.max(1))
        .min(work / MIN_WORK_PER_THREAD + 1)
}

/// Compute-only core writing into a caller-provided buffer (any prior
/// contents — the correction seeding overwrites every element): the
/// workspace path, shared by [`matmul_square_core`] and the lowering's
/// allocation-free entry points.
pub(crate) fn matmul_square_core_into<T: SquareScalar>(
    c_data: &mut [T],
    a: &Matrix<T>,
    b: &Matrix<T>,
    sa: &[T],
    sb: &[T],
    cfg: &EngineConfig,
) {
    assert_eq!(a.cols, b.rows, "contraction mismatch");
    let (m, p) = (a.rows, b.cols);
    assert_eq!(c_data.len(), m * p, "output buffer shape mismatch");
    debug_assert_eq!(sa.len(), m);
    debug_assert_eq!(sb.len(), p);
    let threads = effective_threads(cfg.threads, m, a.cols, p);
    if threads <= 1 {
        block_rows_into(c_data, 0, m, a, b, sa, sb, cfg);
    } else {
        threaded::for_row_chunks(c_data, m, p, threads, |i0, i1, chunk| {
            block_rows_into(chunk, i0, i1, a, b, sa, sb, cfg);
        });
    }
}

/// Compute-only core shared by every public entry point (and by the
/// reference stack in `linalg::matmul`): corrections are supplied by the
/// caller, the ledger is the caller's business.
pub(crate) fn matmul_square_core<T: SquareScalar>(
    a: &Matrix<T>,
    b: &Matrix<T>,
    sa: &[T],
    sb: &[T],
    cfg: &EngineConfig,
) -> Matrix<T> {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_square_core_into(c.data_mut(), a, b, sa, sb, cfg);
    c
}

/// Blocked (and, with `cfg.threads > 1`, multi-threaded) square-based
/// `C = AB`. Bit-exact for `i64`; for floats it is the same arithmetic as
/// [`matmul_square_f64`](super::super::matmul::matmul_square_f64) in a
/// cache-friendly order.
pub fn matmul_square_blocked<T: SquareScalar>(
    a: &Matrix<T>,
    b: &Matrix<T>,
    cfg: &EngineConfig,
) -> (Matrix<T>, OpCounts) {
    assert_eq!(a.cols, b.rows, "contraction mismatch");
    let sa = row_corrections_flat(a);
    let sb = col_corrections_flat(b);
    let c = matmul_square_core(a, b, &sa, &sb, cfg);
    (c, square_matmul_ledger(a.rows, a.cols, b.cols))
}

/// A constant B operand with its `Sb_j` corrections precomputed — the
/// paper's §3 inference case. Build once per model (weights), reuse for
/// every request: each call then pays only the `M·N` activation
/// corrections, never the `N·P` weight corrections.
#[derive(Debug, Clone)]
pub struct PreparedB<T> {
    b: Matrix<T>,
    sb: Vec<T>,
}

impl<T: SquareScalar> PreparedB<T> {
    /// Prepare a weight matrix: computes and caches `Sb`. The returned
    /// ledger is the one-time preparation cost (`N·P` squares).
    pub fn new(b: Matrix<T>) -> (Self, OpCounts) {
        let np = (b.rows * b.cols) as u64;
        let sb = col_corrections_flat(&b);
        (Self { b, sb }, OpCounts { squares: np, adds: np, ..OpCounts::ZERO })
    }

    /// Prepare and wrap for sharing: the serving pool hands every worker
    /// a clone of the returned `Arc`, so the one-time `N·P` correction
    /// cost is paid exactly once no matter how many workers serve the
    /// model (the §3 amortisation, extended across a whole pool).
    pub fn new_shared(b: Matrix<T>) -> (std::sync::Arc<Self>, OpCounts) {
        let (pb, ops) = Self::new(b);
        (std::sync::Arc::new(pb), ops)
    }

    pub fn matrix(&self) -> &Matrix<T> {
        &self.b
    }

    /// The cached `Sb_j = −Σ_k b_kj²` corrections.
    pub fn corrections(&self) -> &[T] {
        &self.sb
    }

    /// Input features a request row must carry (rows of B).
    pub fn in_features(&self) -> usize {
        self.b.rows
    }

    /// Output features per request row (columns of B).
    pub fn out_features(&self) -> usize {
        self.b.cols
    }
}

/// Square-based `C = A·B` against a prepared (constant) B: the per-call
/// ledger drops the `N·P` correction squares that [`PreparedB::new`]
/// already paid.
pub fn matmul_square_prepared<T: SquareScalar>(
    a: &Matrix<T>,
    pb: &PreparedB<T>,
    cfg: &EngineConfig,
) -> (Matrix<T>, OpCounts) {
    assert_eq!(a.cols, pb.b.rows, "contraction mismatch");
    let sa = row_corrections_flat(a);
    let c = matmul_square_core(a, &pb.b, &sa, &pb.sb, cfg);
    (c, square_matmul_const_b_ledger(a.rows, a.cols, pb.b.cols))
}

/// [`matmul_square_prepared`] with every intermediate drawn from reusable
/// buffers — the serving steady state: the activation corrections come
/// from a workspace checkout and the output lands in `c_out` (cleared and
/// resized to `M·P`), so once the buffers are warm the call performs
/// **zero** heap allocations with `cfg.threads == 1` (the scoped threaded
/// driver allocates per spawn by construction). Same values, same
/// hoisted ledger as the allocating form.
pub fn matmul_square_prepared_into<T: SquareScalar>(
    a: &Matrix<T>,
    pb: &PreparedB<T>,
    cfg: &EngineConfig,
    ws: &mut EngineWorkspace<T>,
    c_out: &mut Vec<T>,
) -> OpCounts {
    assert_eq!(a.cols, pb.b.rows, "contraction mismatch");
    let (m, p) = (a.rows, pb.b.cols);
    let mut sa = ws.checkout(m);
    row_corrections_into(a, &mut sa);
    // no zero-fill when the buffer is already the right length: the
    // core's correction seeding overwrites every element anyway
    if c_out.len() != m * p {
        c_out.clear();
        c_out.resize(m * p, T::default());
    }
    matmul_square_core_into(c_out, a, &pb.b, &sa, &pb.sb, cfg);
    ws.give_back(sa);
    square_matmul_const_b_ledger(m, a.cols, p)
}

/// Hoisted ledger of ONE `mi`-row tile of the §3.3 tiled operation:
/// `mi·N·P` window squares, the `mi·P` correction seeds, and the trailing
/// exact ÷2 — and **zero** correction squares, because §3.3 hoists the
/// full-row/full-column corrections once per request, never per tile.
/// Summed over any disjoint tiling of `[0, M)` and added to the one-time
/// [`row_corrections_ledger`] hoist, this reproduces
/// [`square_matmul_const_b_ledger`] exactly (the tests assert it).
pub fn square_matmul_tile_ledger(mi: usize, n: usize, p: usize) -> OpCounts {
    let (mi, n, p) = (mi as u64, n as u64, p as u64);
    OpCounts {
        mults: 0,
        squares: mi * n * p,
        adds: mi * p + 2 * mi * n * p,
        shifts: mi * p,
    }
}

/// The one-time per-request hoist ledger: the `M·N` squares and adds
/// [`row_corrections_into`] spends computing `Sa_i` from the FULL rows of
/// the request — paid exactly once no matter how many tiles the request
/// is split into.
pub fn row_corrections_ledger(m: usize, n: usize) -> OpCounts {
    let mn = (m * n) as u64;
    OpCounts { squares: mn, adds: mn, ..OpCounts::ZERO }
}

/// §3.3 tile entry, generic-B form: compute the contiguous output-row
/// partition `[i0, i1)` of `C = A·B` into `c_rows` — exactly that
/// partition's row-major storage, a *disjoint sub-slice* of the request's
/// output, so concurrent tiles of one request need no locking. Both
/// corrections are supplied by the caller, hoisted ONCE per request from
/// the full rows/columns of the large operands (`sa` via
/// [`row_corrections_into`], `sb` via a cache such as [`PreparedB`] or
/// the CPM3 pass operands) — never recomputed per tile, which is why the
/// returned [`square_matmul_tile_ledger`] carries no correction squares.
/// Values are byte-identical to the untiled core: the per-row arithmetic
/// (seed, k-blocked sweep, ÷2) is the same code path.
pub fn matmul_square_tile_into<T: SquareScalar>(
    a: &Matrix<T>,
    b: &Matrix<T>,
    sa: &[T],
    sb: &[T],
    i0: usize,
    i1: usize,
    c_rows: &mut [T],
    cfg: &EngineConfig,
) -> OpCounts {
    assert_eq!(a.cols, b.rows, "contraction mismatch");
    assert!(i0 <= i1 && i1 <= a.rows, "tile row range out of bounds");
    assert_eq!(
        c_rows.len(),
        (i1 - i0) * b.cols,
        "tile output slice must hold exactly its partition"
    );
    debug_assert_eq!(sa.len(), a.rows);
    debug_assert_eq!(sb.len(), b.cols);
    block_rows_into(c_rows, i0, i1, a, b, sa, sb, cfg);
    square_matmul_tile_ledger(i1 - i0, a.cols, b.cols)
}

/// [`matmul_square_tile_into`] against a prepared (constant) B — the
/// serving form: `Sb` comes from the [`PreparedB`] cache, `Sa` from the
/// request-wide hoist the caller performed once. This is the entry point
/// the tiled serving executors (dense, conv post-im2col, CPM3 passes)
/// share.
pub fn matmul_square_prepared_tile_into<T: SquareScalar>(
    a: &Matrix<T>,
    pb: &PreparedB<T>,
    sa: &[T],
    i0: usize,
    i1: usize,
    c_rows: &mut [T],
    cfg: &EngineConfig,
) -> OpCounts {
    matmul_square_tile_into(a, &pb.b, sa, &pb.sb, i0, i1, c_rows, cfg)
}

/// Direct `C = AB` in the same blocked row-sliced form — the multiplier
/// baseline for perf comparisons and the shadow executor.
pub fn matmul_direct_blocked<T: SquareScalar>(
    a: &Matrix<T>,
    b: &Matrix<T>,
    cfg: &EngineConfig,
) -> (Matrix<T>, OpCounts) {
    let mut c = Matrix::zeros(a.rows, b.cols);
    let ops = matmul_direct_into_slice(c.data_mut(), a, b, cfg);
    (c, ops)
}

/// [`matmul_direct_blocked`] into a reused output buffer (`c_out` is
/// cleared, resized to `M·P` and zero-seeded — the multiplier kernel
/// accumulates, so unlike the square core's correction seeding a fresh
/// zero fill is required): the workspace path of the *shadow* twins, so
/// a warmed shadowed batch allocates nothing either. Same values, same
/// ledger as the allocating form.
pub fn matmul_direct_blocked_into<T: SquareScalar>(
    a: &Matrix<T>,
    b: &Matrix<T>,
    cfg: &EngineConfig,
    c_out: &mut Vec<T>,
) -> OpCounts {
    let (m, p) = (a.rows, b.cols);
    c_out.clear();
    c_out.resize(m * p, T::default());
    matmul_direct_into_slice(c_out, a, b, cfg)
}

/// The shared direct-matmul core over a zeroed output slice.
fn matmul_direct_into_slice<T: SquareScalar>(
    c_data: &mut [T],
    a: &Matrix<T>,
    b: &Matrix<T>,
    cfg: &EngineConfig,
) -> OpCounts {
    assert_eq!(a.cols, b.rows, "contraction mismatch");
    let (m, n, p) = (a.rows, a.cols, b.cols);
    assert_eq!(c_data.len(), m * p, "output buffer shape mismatch");
    let threads = effective_threads(cfg.threads, m, n, p);
    if threads <= 1 {
        tile_sweep(c_data, 0, m, a, b, cfg, kernels::mul_acc_row);
    } else {
        threaded::for_row_chunks(c_data, m, p, threads, |i0, i1, chunk| {
            tile_sweep(chunk, i0, i1, a, b, cfg, kernels::mul_acc_row);
        });
    }
    let mnp = (m * n * p) as u64;
    OpCounts { mults: mnp, adds: mnp, ..OpCounts::ZERO }
}

/// The pre-engine baseline: per-element `get`/`set` square-based matmul,
/// exactly as the seed tree computed it. Kept (unused by the hot path) as
/// the comparison point for the `blocked_engine` perf gate and as a
/// second, independently-written implementation for the equivalence tests.
pub fn matmul_square_naive<T: SquareScalar>(a: &Matrix<T>, b: &Matrix<T>) -> Matrix<T> {
    assert_eq!(a.cols, b.rows, "contraction mismatch");
    let (m, n, p) = (a.rows, a.cols, b.cols);
    let sa: Vec<T> = (0..m)
        .map(|i| {
            let mut acc = T::default();
            for k in 0..n {
                acc += a.get(i, k) * a.get(i, k);
            }
            -acc
        })
        .collect();
    let sb: Vec<T> = (0..p)
        .map(|j| {
            let mut acc = T::default();
            for k in 0..n {
                acc += b.get(k, j) * b.get(k, j);
            }
            -acc
        })
        .collect();
    let mut c = Matrix::zeros(m, p);
    for i in 0..m {
        for j in 0..p {
            let mut acc = sa[i] + sb[j];
            for k in 0..n {
                let s = a.get(i, k) + b.get(k, j);
                acc += s * s;
            }
            c.set(i, j, acc.halve());
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::super::super::matmul::{matmul_direct, matmul_direct_f64, matmul_square};
    use super::*;
    use crate::testkit::{forall, Rng};

    fn tiny_cfg(threads: usize) -> EngineConfig {
        // tiny tiles so even small matrices cross several block boundaries
        EngineConfig { block_k: 3, block_n: 5, threads }
    }

    #[test]
    fn blocked_matches_direct_and_naive_across_shapes() {
        forall(
            0xB10C,
            60,
            |rng, size| {
                let m = rng.usize_in(1, size.max(1).min(14));
                let n = rng.usize_in(1, size.max(1).min(14));
                let p = rng.usize_in(1, size.max(1).min(14));
                (
                    Matrix::random(rng, m, n, -1000, 1000),
                    Matrix::random(rng, n, p, -1000, 1000),
                )
            },
            |(a, b)| {
                let want = matmul_direct(a, b).0;
                let (got, _) = matmul_square_blocked(a, b, &tiny_cfg(1));
                if got != want {
                    return Err(format!(
                        "blocked mismatch at {}x{}x{}",
                        a.rows, a.cols, b.cols
                    ));
                }
                if matmul_square_naive(a, b) != want {
                    return Err("naive mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tile_ledgers_and_one_hoist_equal_the_prepared_ledger() {
        // the §3.3 amortisation, asserted per element: an uneven tile
        // partition reassembles the untiled values byte-for-byte, and
        // Σ square_matmul_tile_ledger + one row_corrections_ledger hoist
        // == square_matmul_const_b_ledger
        let mut rng = Rng::new(0x711E);
        let a = Matrix::random(&mut rng, 9, 7, -200, 200);
        let b = Matrix::random(&mut rng, 7, 5, -200, 200);
        let (pb, _) = PreparedB::new(b.clone());
        let (want, want_ops) = matmul_square_prepared(&a, &pb, &tiny_cfg(1));

        let mut sa = vec![0i64; a.rows];
        row_corrections_into(&a, &mut sa);
        let mut ops = row_corrections_ledger(a.rows, a.cols);
        let mut c = vec![0i64; a.rows * b.cols];
        for (i0, i1) in [(0usize, 2usize), (2, 3), (3, 9)] {
            let rows = &mut c[i0 * b.cols..i1 * b.cols];
            let tile_ops =
                matmul_square_prepared_tile_into(&a, &pb, &sa, i0, i1, rows, &tiny_cfg(1));
            assert_eq!(tile_ops, square_matmul_tile_ledger(i1 - i0, a.cols, b.cols));
            ops += tile_ops;
        }
        assert_eq!(&c[..], want.data(), "tiles must reassemble the untiled values");
        assert_eq!(ops, want_ops, "tile ledgers + one hoist ≠ the prepared ledger");
        assert_eq!(ops, square_matmul_const_b_ledger(a.rows, a.cols, b.cols));
    }

    #[test]
    fn threaded_equals_single_threaded() {
        let mut rng = Rng::new(0x7412);
        for (m, n, p) in [(1usize, 7usize, 9usize), (5, 16, 3), (33, 20, 41), (64, 64, 64)] {
            let a = Matrix::random(&mut rng, m, n, -500, 500);
            let b = Matrix::random(&mut rng, n, p, -500, 500);
            let (single, ops1) = matmul_square_blocked(&a, &b, &tiny_cfg(1));
            let (multi, ops4) = matmul_square_blocked(&a, &b, &tiny_cfg(4));
            assert_eq!(single, multi, "{m}x{n}x{p}");
            assert_eq!(ops1, ops4);
        }
    }

    #[test]
    fn ledger_matches_reference_matmul_square() {
        let mut rng = Rng::new(0x1ED6);
        for (m, n, p) in [(1usize, 1usize, 1usize), (4, 6, 3), (16, 16, 16), (7, 11, 5)] {
            let a = Matrix::random(&mut rng, m, n, -100, 100);
            let b = Matrix::random(&mut rng, n, p, -100, 100);
            let (c_ref, ops_ref) = matmul_square(&a, &b);
            let (c, ops) = matmul_square_blocked(&a, &b, &EngineConfig::default());
            assert_eq!(c, c_ref);
            assert_eq!(ops, ops_ref, "hoisted engine ledger diverged at {m}x{n}x{p}");
        }
    }

    #[test]
    fn prepared_b_amortises_weight_corrections() {
        let mut rng = Rng::new(0xCAC4E);
        let a = Matrix::random(&mut rng, 6, 8, -50, 50);
        let b = Matrix::random(&mut rng, 8, 4, -50, 50);
        let (full, full_ops) = matmul_square_blocked(&a, &b, &tiny_cfg(1));
        let (pb, prep_ops) = PreparedB::new(b);
        assert_eq!(pb.in_features(), 8);
        assert_eq!(pb.out_features(), 4);
        let (amortised, call_ops) = matmul_square_prepared(&a, &pb, &tiny_cfg(2));
        assert_eq!(amortised, full);
        // one-time prep + per-call == full ledger (the §3 amortisation claim)
        assert_eq!(call_ops.squares + prep_ops.squares, full_ops.squares);
        assert_eq!(call_ops.squares, 6 * 8 * 4 + 6 * 8);
    }

    #[test]
    fn f32_engine_is_exact_on_integer_data() {
        // integer-valued f32 inputs keep every intermediate below 2^24, so
        // the float engine must agree exactly with the f64 direct product
        let mut rng = Rng::new(0xF32);
        let ai = Matrix::random(&mut rng, 9, 13, -64, 64);
        let bi = Matrix::random(&mut rng, 13, 7, -64, 64);
        let a32 = ai.map(|v| v as f32);
        let b32 = bi.map(|v| v as f32);
        let (c32, _) = matmul_square_blocked(&a32, &b32, &tiny_cfg(2));
        let want = matmul_direct_f64(&ai.map(|v| v as f64), &bi.map(|v| v as f64));
        for (g, w) in c32.data().iter().zip(want.data()) {
            assert_eq!(*g as f64, *w);
        }
    }

    #[test]
    fn direct_blocked_matches_reference() {
        let mut rng = Rng::new(0xD1);
        let a = Matrix::random(&mut rng, 12, 19, -300, 300);
        let b = Matrix::random(&mut rng, 19, 8, -300, 300);
        let (want, want_ops) = matmul_direct(&a, &b);
        let (got, ops) = matmul_direct_blocked(&a, &b, &tiny_cfg(3));
        assert_eq!(got, want);
        assert_eq!(ops, want_ops);
    }

    #[test]
    fn direct_into_matches_allocating_form_and_rezeroes() {
        let mut rng = Rng::new(0xD2);
        let a = Matrix::random(&mut rng, 8, 11, -90, 90);
        let b = Matrix::random(&mut rng, 11, 6, -90, 90);
        let (want, want_ops) = matmul_direct_blocked(&a, &b, &tiny_cfg(2));
        let mut c = Vec::new();
        // the multiplier kernel accumulates: round 2+ reuse a dirty
        // buffer, so any missing re-zero would double the values
        for round in 0..3 {
            let ops = matmul_direct_blocked_into(&a, &b, &tiny_cfg(2), &mut c);
            assert_eq!(c, want.data(), "round {round}: stale accumulation");
            assert_eq!(ops, want_ops);
        }
    }

    #[test]
    fn prepared_into_matches_allocating_form() {
        let mut rng = Rng::new(0x17E0);
        let a = Matrix::random(&mut rng, 9, 7, -60, 60);
        let b = Matrix::random(&mut rng, 7, 5, -60, 60);
        let (pb, _) = PreparedB::new(b);
        let (want, want_ops) = matmul_square_prepared(&a, &pb, &tiny_cfg(1));
        let mut ws = EngineWorkspace::new();
        let mut c = Vec::new();
        for round in 0..3 {
            let ops = matmul_square_prepared_into(&a, &pb, &tiny_cfg(1), &mut ws, &mut c);
            assert_eq!(c, want.data(), "round {round}");
            assert_eq!(ops, want_ops);
        }
        assert_eq!(ws.grows(), 1, "only the warm-up checkout may allocate");

        let mut sa = vec![0i64; a.rows];
        row_corrections_into(&a, &mut sa);
        assert_eq!(sa, row_corrections_flat(&a));
    }

    #[test]
    fn degenerate_empty_shapes() {
        let a: Matrix<i64> = Matrix::zeros(0, 5);
        let b: Matrix<i64> = Matrix::zeros(5, 4);
        let (c, _) = matmul_square_blocked(&a, &b, &EngineConfig::threaded());
        assert_eq!((c.rows, c.cols), (0, 4));
        let a: Matrix<i64> = Matrix::zeros(3, 0);
        let b: Matrix<i64> = Matrix::zeros(0, 2);
        let (c, _) = matmul_square_blocked(&a, &b, &EngineConfig::default());
        assert_eq!(c, Matrix::zeros(3, 2));
    }
}
