//! Real matrix multiplication: direct (eq. 3) vs square-based (eq. 4/5),
//! both with exact operation ledgers.

use super::counts::OpCounts;
use super::engine::{self, EngineConfig};
use super::matrix::Matrix;

/// Direct `C = AB` (eq. 3), counting M·N·P multiplications.
///
/// Hot loop is i-k-j order over contiguous rows (§Perf-L3); the ledger is
/// hoisted out of the loop — it is a deterministic function of the shape
/// (M·N·P mults/adds), asserted equivalent by the ledger tests below.
pub fn matmul_direct(a: &Matrix<i64>, b: &Matrix<i64>) -> (Matrix<i64>, OpCounts) {
    assert_eq!(a.cols, b.rows, "contraction mismatch");
    let (m, n, p) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, p);
    for i in 0..m {
        let a_row = a.row(i);
        for k in 0..n {
            let aik = a_row[k];
            let b_row = b.row(k);
            let c_row = &mut c.data_mut()[i * p..(i + 1) * p];
            for j in 0..p {
                c_row[j] += aik * b_row[j];
            }
        }
    }
    let mnp = (m * n * p) as u64;
    let ops = OpCounts { mults: mnp, adds: mnp, ..OpCounts::ZERO };
    (c, ops)
}

/// Row corrections `Sa_i = −Σ_k a_ik²` (eq. 5). M·N squares, ledger
/// hoisted (one square + one add per element of A).
pub fn row_corrections(a: &Matrix<i64>, ops: &mut OpCounts) -> Vec<i64> {
    let mn = (a.rows * a.cols) as u64;
    ops.squares += mn;
    ops.adds += mn;
    engine::row_corrections_flat(a)
}

/// Column corrections `Sb_j = −Σ_k b_kj²` (eq. 5). N·P squares, ledger
/// hoisted; the engine sweeps rows so the access stays contiguous.
pub fn col_corrections(b: &Matrix<i64>, ops: &mut OpCounts) -> Vec<i64> {
    let np = (b.rows * b.cols) as u64;
    ops.squares += np;
    ops.adds += np;
    engine::col_corrections_flat(b)
}

/// Square-based `C = AB` via eq. (4): `½(Sab_ij + Sa_i + Sb_j)`.
///
/// Ledger: exactly `M·N·P + M·N + N·P` squares and **zero** general
/// multiplications — the claim behind eq. (6).
pub fn matmul_square(a: &Matrix<i64>, b: &Matrix<i64>) -> (Matrix<i64>, OpCounts) {
    assert_eq!(a.cols, b.rows, "contraction mismatch");
    let mut ops = OpCounts::ZERO;
    let sa = row_corrections(a, &mut ops);
    let sb = col_corrections(b, &mut ops);
    let (m, n, p) = (a.rows, a.cols, b.cols);

    // hot loop delegated to the cache-blocked engine core (§Perf-L3):
    // row-sliced i-k-j with the rank-1 correction seed (Fig. 1b register
    // protocol) and the trailing exact ÷2 of eq. (4)
    let c = engine::blocked::matmul_square_core(a, b, &sa, &sb, &EngineConfig::default());

    // ledger, hoisted (deterministic in the shape; tests assert eq. 5):
    // M·N·P window squares, 2 adds each, plus the per-output seed add/shift
    let mnp = (m * n * p) as u64;
    ops.squares += mnp;
    ops.adds += 2 * mnp + (m * p) as u64;
    ops.shifts += (m * p) as u64;
    (c, ops)
}

/// Square-based matmul where `b` is constant and its `Sb_j` corrections are
/// pre-computed (the paper's AI-inference case, §3): the per-call ledger
/// drops the N·P correction squares.
pub fn matmul_square_const_b(
    a: &Matrix<i64>,
    b: &Matrix<i64>,
    sb: &[i64],
) -> (Matrix<i64>, OpCounts) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(sb.len(), b.cols);
    let (m, n, p) = (a.rows, a.cols, b.cols);
    let mut ops = OpCounts::ZERO;
    let sa = row_corrections(a, &mut ops);

    // row-sliced i-k-j through the blocked engine core — same inner loops
    // as matmul_square, minus the Sb computation the caller amortised
    let c = engine::blocked::matmul_square_core(a, b, &sa, sb, &EngineConfig::default());

    // hoisted per-call ledger: the N·P correction squares are gone
    let mnp = (m * n * p) as u64;
    ops.squares += mnp;
    ops.adds += 2 * mnp + (m * p) as u64;
    ops.shifts += (m * p) as u64;
    (c, ops)
}

/// f64 twin of [`matmul_direct`] (no ledger) for the error experiment.
pub fn matmul_direct_f64(a: &Matrix<f64>, b: &Matrix<f64>) -> Matrix<f64> {
    assert_eq!(a.cols, b.rows);
    Matrix::from_fn(a.rows, b.cols, |i, j| {
        (0..a.cols).map(|k| a.get(i, k) * b.get(k, j)).sum()
    })
}

/// f64 twin of [`matmul_square`] (no ledger) for the error experiment.
pub fn matmul_square_f64(a: &Matrix<f64>, b: &Matrix<f64>) -> Matrix<f64> {
    assert_eq!(a.cols, b.rows);
    let sa: Vec<f64> = (0..a.rows)
        .map(|i| -a.row(i).iter().map(|&x| x * x).sum::<f64>())
        .collect();
    let sb: Vec<f64> = (0..b.cols)
        .map(|j| -(0..b.rows).map(|k| b.get(k, j) * b.get(k, j)).sum::<f64>())
        .collect();
    Matrix::from_fn(a.rows, b.cols, |i, j| {
        let sab: f64 = (0..a.cols)
            .map(|k| {
                let s = a.get(i, k) + b.get(k, j);
                s * s
            })
            .sum();
        0.5 * (sab + sa[i] + sb[j])
    })
}

/// f32 twin (everything accumulated in f32) for the error experiment.
pub fn matmul_square_f32(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    assert_eq!(a.cols, b.rows);
    let sa: Vec<f32> = (0..a.rows)
        .map(|i| -a.row(i).iter().map(|&x| x * x).sum::<f32>())
        .collect();
    let sb: Vec<f32> = (0..b.cols)
        .map(|j| -(0..b.rows).map(|k| b.get(k, j) * b.get(k, j)).sum::<f32>())
        .collect();
    Matrix::from_fn(a.rows, b.cols, |i, j| {
        let sab: f32 = (0..a.cols)
            .map(|k| {
                let s = a.get(i, k) + b.get(k, j);
                s * s
            })
            .sum();
        0.5 * (sab + sa[i] + sb[j])
    })
}

pub fn matmul_direct_f32(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    assert_eq!(a.cols, b.rows);
    Matrix::from_fn(a.rows, b.cols, |i, j| {
        (0..a.cols).map(|k| a.get(i, k) * b.get(k, j)).sum()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Rng};

    #[test]
    fn square_matmul_exact() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let (m, n, p) = (
                rng.usize_in(1, 12),
                rng.usize_in(1, 12),
                rng.usize_in(1, 12),
            );
            let a = Matrix::random(&mut rng, m, n, -1000, 1000);
            let b = Matrix::random(&mut rng, n, p, -1000, 1000);
            let (direct, _) = matmul_direct(&a, &b);
            let (square, _) = matmul_square(&a, &b);
            assert_eq!(direct, square);
        }
    }

    #[test]
    fn ledgers_match_paper_formulas() {
        for (m, n, p) in [(1, 1, 1), (4, 6, 3), (16, 16, 16), (7, 11, 5)] {
            let mut rng = Rng::new(2);
            let a = Matrix::random(&mut rng, m, n, -100, 100);
            let b = Matrix::random(&mut rng, n, p, -100, 100);
            let (_, d) = matmul_direct(&a, &b);
            let (_, s) = matmul_square(&a, &b);
            let (m, n, p) = (m as u64, n as u64, p as u64);
            assert_eq!(d.mults, m * n * p);
            assert_eq!(d.squares, 0);
            assert_eq!(s.mults, 0);
            // paper §3: M·N·P + M·N + N·P squares
            assert_eq!(s.squares, m * n * p + m * n + n * p);
        }
    }

    #[test]
    fn eq6_ratio_measured() {
        for (m, n, p) in [(2, 8, 2), (8, 8, 8), (32, 16, 32)] {
            let mut rng = Rng::new(3);
            let a = Matrix::random(&mut rng, m, n, -10, 10);
            let b = Matrix::random(&mut rng, n, p, -10, 10);
            let (_, d) = matmul_direct(&a, &b);
            let (_, s) = matmul_square(&a, &b);
            let measured = s.square_ratio_vs(&d);
            let analytic = super::super::counts::eq6_ratio(m as u64, p as u64);
            assert!((measured - analytic).abs() < 1e-12,
                    "m={m} p={p}: {measured} vs {analytic}");
        }
    }

    #[test]
    fn const_b_drops_np_squares() {
        let mut rng = Rng::new(4);
        let a = Matrix::random(&mut rng, 6, 8, -50, 50);
        let b = Matrix::random(&mut rng, 8, 4, -50, 50);
        let mut pre = OpCounts::ZERO;
        let sb = col_corrections(&b, &mut pre);
        let (c1, amortised) = matmul_square_const_b(&a, &b, &sb);
        let (c2, full) = matmul_square(&a, &b);
        assert_eq!(c1, c2);
        assert_eq!(amortised.squares + pre.squares, full.squares);
        assert_eq!(amortised.squares, 6 * 8 * 4 + 6 * 8);
    }

    #[test]
    fn square_matmul_property() {
        forall(
            99,
            60,
            |rng, size| {
                let m = rng.usize_in(1, size.max(1).min(10));
                let n = rng.usize_in(1, size.max(1).min(10));
                let p = rng.usize_in(1, size.max(1).min(10));
                (
                    Matrix::random(rng, m, n, -(1 << 15), 1 << 15),
                    Matrix::random(rng, n, p, -(1 << 15), 1 << 15),
                )
            },
            |(a, b)| {
                let (d, _) = matmul_direct(a, b);
                let (s, _) = matmul_square(a, b);
                if d == s {
                    Ok(())
                } else {
                    Err(format!("mismatch at {}x{}x{}", a.rows, a.cols, b.cols))
                }
            },
        );
    }

    #[test]
    fn const_b_matches_square_matmul_property() {
        // the row-sliced i-k-j rewrite must be bit-identical to the full
        // square path whenever it is handed the true Sb corrections
        forall(
            0xCB,
            60,
            |rng, size| {
                let m = rng.usize_in(1, size.max(1).min(12));
                let n = rng.usize_in(1, size.max(1).min(12));
                let p = rng.usize_in(1, size.max(1).min(12));
                (
                    Matrix::random(rng, m, n, -(1 << 12), 1 << 12),
                    Matrix::random(rng, n, p, -(1 << 12), 1 << 12),
                )
            },
            |(a, b)| {
                let mut pre = OpCounts::ZERO;
                let sb = col_corrections(b, &mut pre);
                let (c_const, ops_const) = matmul_square_const_b(a, b, &sb);
                let (c_full, ops_full) = matmul_square(a, b);
                if c_const != c_full {
                    return Err(format!(
                        "value mismatch at {}x{}x{}",
                        a.rows, a.cols, b.cols
                    ));
                }
                if ops_const + pre != ops_full {
                    return Err(format!(
                        "ledger mismatch: {ops_const} + {pre} != {ops_full}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn hoisted_ledgers_equal_engine_formulas() {
        use crate::linalg::engine::{square_matmul_const_b_ledger, square_matmul_ledger};
        let mut rng = Rng::new(0x4ED);
        for (m, n, p) in [(1usize, 1usize, 1usize), (3, 9, 2), (16, 8, 16)] {
            let a = Matrix::random(&mut rng, m, n, -100, 100);
            let b = Matrix::random(&mut rng, n, p, -100, 100);
            let (_, s) = matmul_square(&a, &b);
            assert_eq!(s, square_matmul_ledger(m, n, p));
            let mut pre = OpCounts::ZERO;
            let sb = col_corrections(&b, &mut pre);
            let (_, sc) = matmul_square_const_b(&a, &b, &sb);
            assert_eq!(sc, square_matmul_const_b_ledger(m, n, p));
        }
    }

    #[test]
    fn f64_twins_agree_closely() {
        let mut rng = Rng::new(5);
        let a = Matrix::random_normal(&mut rng, 16, 32);
        let b = Matrix::random_normal(&mut rng, 32, 8);
        let d = matmul_direct_f64(&a, &b);
        let s = matmul_square_f64(&a, &b);
        assert!(d.max_abs_diff(&s) < 1e-10);
    }
}
