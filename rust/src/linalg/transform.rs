//! Linear transforms: direct (eq. 7) vs square-based (eq. 8/9), real and
//! complex (eq. 23–26 CPM, eq. 39–43 CPM3), with ledgers.

use crate::arith::complex::{cmul_direct, Complex};

use super::counts::OpCounts;
use super::matrix::Matrix;

/// Direct transform X_k = Σ_i w_ki·x_i (eq. 7): N² multiplications.
pub fn transform_direct(w: &Matrix<i64>, x: &[i64]) -> (Vec<i64>, OpCounts) {
    assert_eq!(w.cols, x.len());
    let mut ops = OpCounts::ZERO;
    let out = (0..w.rows)
        .map(|k| {
            (0..w.cols)
                .map(|i| {
                    ops.mult();
                    ops.add();
                    w.get(k, i) * x[i]
                })
                .sum()
        })
        .collect();
    (out, ops)
}

/// Pre-computed coefficient corrections `Sw_k = −Σ_i w_ki²` (eq. 9).
pub fn transform_corrections(w: &Matrix<i64>, ops: &mut OpCounts) -> Vec<i64> {
    (0..w.rows)
        .map(|k| {
            -w.row(k)
                .iter()
                .map(|&v| {
                    ops.square();
                    ops.add();
                    v * v
                })
                .sum::<i64>()
        })
        .collect()
}

/// Square-based transform (eq. 8, the Fig. 6b engine) with pre-computed
/// `sw` (the paper's "coefficients are constants" case): N² + N squares
/// per transform — the common x_i² term is computed once per sample.
pub fn transform_square(
    w: &Matrix<i64>,
    x: &[i64],
    sw: &[i64],
) -> (Vec<i64>, OpCounts) {
    assert_eq!(w.cols, x.len());
    assert_eq!(sw.len(), w.rows);
    let mut ops = OpCounts::ZERO;

    // Σ x² — N squares, shared by every output (the single square unit at
    // the input of Fig. 6b)
    let sx: i64 = x
        .iter()
        .map(|&v| {
            ops.square();
            ops.add();
            v * v
        })
        .sum();

    let out = (0..w.rows)
        .map(|k| {
            let mut acc = sw[k] - sx;
            ops.add();
            for i in 0..w.cols {
                let s = w.get(k, i) + x[i];
                acc += s * s;
                ops.square();
                ops.add_n(2);
            }
            ops.shift();
            acc >> 1
        })
        .collect();
    (out, ops)
}

/// Direct complex transform (eq. 23).
pub fn ctransform_direct(
    w: &Matrix<Complex<i64>>,
    x: &[Complex<i64>],
) -> (Vec<Complex<i64>>, OpCounts) {
    assert_eq!(w.cols, x.len());
    let mut ops = OpCounts::ZERO;
    let out = (0..w.rows)
        .map(|k| {
            let mut acc = Complex::ZERO;
            for i in 0..w.cols {
                acc += cmul_direct(w.get(k, i), x[i]);
                ops.mults += 4;
                ops.add_n(4);
            }
            acc
        })
        .collect();
    (out, ops)
}

/// Complex transform with CPM (eq. 24–26, Fig. 10), pre-computed `S_k`.
pub fn ctransform_cpm(
    w: &Matrix<Complex<i64>>,
    x: &[Complex<i64>],
    sk: &[i64],
) -> (Vec<Complex<i64>>, OpCounts) {
    assert_eq!(w.cols, x.len());
    assert_eq!(sk.len(), w.rows);
    let mut ops = OpCounts::ZERO;

    // Sxy = −Σ(x²+y²) — 2N squares shared by all outputs (eq. 25)
    let sxy: i64 = -x
        .iter()
        .map(|v| {
            ops.squares += 2;
            ops.add_n(2);
            v.re * v.re + v.im * v.im
        })
        .sum::<i64>();

    let out = (0..w.rows)
        .map(|k| {
            let corr = sxy + sk[k];
            ops.add();
            let (mut re, mut im) = (corr, corr);
            for i in 0..w.cols {
                let cv = w.get(k, i);
                let xv = x[i];
                let t1 = cv.re + xv.re;
                let t2 = cv.im - xv.im;
                let t3 = cv.re + xv.im;
                let t4 = cv.im + xv.re;
                re += t1 * t1 + t2 * t2;
                im += t3 * t3 + t4 * t4;
                ops.squares += 4;
                ops.add_n(8);
            }
            ops.shifts += 2;
            Complex::new(re >> 1, im >> 1)
        })
        .collect();
    (out, ops)
}

/// `S_k = −Σ_i (c_ki² + s_ki²)` of eq. (25) — pre-computable.
pub fn ctransform_cpm_corrections(
    w: &Matrix<Complex<i64>>,
    ops: &mut OpCounts,
) -> Vec<i64> {
    (0..w.rows)
        .map(|k| {
            -w.row(k)
                .iter()
                .map(|v| {
                    ops.squares += 2;
                    ops.add_n(2);
                    v.re * v.re + v.im * v.im
                })
                .sum::<i64>()
        })
        .collect()
}

/// CPM3 coefficient corrections `(Sx_k, Sy_k)` of eq. (41)/(43).
pub fn ctransform_cpm3_corrections(
    w: &Matrix<Complex<i64>>,
    ops: &mut OpCounts,
) -> (Vec<i64>, Vec<i64>) {
    let mut sxk = vec![0i64; w.rows];
    let mut syk = vec![0i64; w.rows];
    for k in 0..w.rows {
        for v in w.row(k) {
            let c2 = v.re * v.re;
            let cs = v.re + v.im;
            let sc = v.im - v.re;
            sxk[k] += -c2 + cs * cs;
            syk[k] += -c2 - sc * sc;
            ops.squares += 3;
            ops.add_n(6);
        }
    }
    (sxk, syk)
}

/// Complex transform with CPM3 (eq. 39–43, Fig. 13), pre-computed
/// corrections.
pub fn ctransform_cpm3(
    w: &Matrix<Complex<i64>>,
    x: &[Complex<i64>],
    sxk: &[i64],
    syk: &[i64],
) -> (Vec<Complex<i64>>, OpCounts) {
    assert_eq!(w.cols, x.len());
    let mut ops = OpCounts::ZERO;

    // common sample terms (eq. 41/43): 3 squares per sample, shared
    let mut sxy = 0i64;
    let mut syx = 0i64;
    for v in x {
        let xy = v.re + v.im;
        let xy2 = xy * xy;
        sxy += -xy2 + v.im * v.im;
        syx += -xy2 - v.re * v.re;
        ops.squares += 3;
        ops.add_n(5);
    }

    let out = (0..w.rows)
        .map(|k| {
            let mut re = sxy + sxk[k];
            let mut im = syx + syk[k];
            ops.add_n(2);
            for i in 0..w.cols {
                let cv = w.get(k, i);
                let xv = x[i];
                let t = cv.re + xv.re + xv.im; // c + x + y — shared
                let t = t * t;
                let u = xv.im + cv.re + cv.im; // y + c + s
                let v2 = xv.re + cv.im - cv.re; // x + s − c
                re += t - u * u;
                im += t + v2 * v2;
                ops.squares += 3;
                ops.add_n(8);
            }
            ops.shifts += 2;
            Complex::new(re >> 1, im >> 1)
        })
        .collect();
    (out, ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    #[test]
    fn real_transform_exact() {
        let mut rng = Rng::new(30);
        for _ in 0..30 {
            let n = rng.usize_in(1, 16);
            let w = Matrix::random(&mut rng, n, n, -300, 300);
            let x = rng.vec_i64(n, -300, 300);
            let (d, _) = transform_direct(&w, &x);
            let mut pre = OpCounts::ZERO;
            let sw = transform_corrections(&w, &mut pre);
            let (s, _) = transform_square(&w, &x, &sw);
            assert_eq!(d, s);
        }
    }

    #[test]
    fn real_transform_ledger_is_n_plus_1_squares_per_output() {
        // §4: N+1 squares per output (amortised) vs N multipliers
        let mut rng = Rng::new(31);
        let n = 16usize;
        let w = Matrix::random(&mut rng, n, n, -100, 100);
        let x = rng.vec_i64(n, -100, 100);
        let mut pre = OpCounts::ZERO;
        let sw = transform_corrections(&w, &mut pre);
        let (_, ops) = transform_square(&w, &x, &sw);
        // per transform: N² window squares + N shared x² squares
        assert_eq!(ops.squares as usize, n * n + n);
        assert_eq!(pre.squares as usize, n * n); // one-off Sw cost
    }

    fn rand_cvec(rng: &mut Rng, n: usize, lim: i64) -> Vec<Complex<i64>> {
        (0..n)
            .map(|_| Complex::new(rng.i64_in(-lim, lim), rng.i64_in(-lim, lim)))
            .collect()
    }

    #[test]
    fn complex_transforms_exact() {
        let mut rng = Rng::new(32);
        for _ in 0..20 {
            let n = rng.usize_in(1, 12);
            let w = Matrix::from_fn(n, n, |_, _| {
                Complex::new(rng.i64_in(-200, 200), rng.i64_in(-200, 200))
            });
            let x = rand_cvec(&mut rng, n, 200);
            let (d, _) = ctransform_direct(&w, &x);

            let mut pre = OpCounts::ZERO;
            let sk = ctransform_cpm_corrections(&w, &mut pre);
            let (c4, _) = ctransform_cpm(&w, &x, &sk);
            assert_eq!(d, c4);

            let mut pre3 = OpCounts::ZERO;
            let (sxk, syk) = ctransform_cpm3_corrections(&w, &mut pre3);
            let (c3, _) = ctransform_cpm3(&w, &x, &sxk, &syk);
            assert_eq!(d, c3);
        }
    }

    #[test]
    fn cpm3_transform_ledger() {
        let mut rng = Rng::new(33);
        let n = 8usize;
        let w = Matrix::from_fn(n, n, |_, _| {
            Complex::new(rng.i64_in(-50, 50), rng.i64_in(-50, 50))
        });
        let x = rand_cvec(&mut rng, n, 50);
        let mut pre = OpCounts::ZERO;
        let (sxk, syk) = ctransform_cpm3_corrections(&w, &mut pre);
        let (_, ops) = ctransform_cpm3(&w, &x, &sxk, &syk);
        // 3 squares per (k,i) + 3 per sample; corrections pre-computed
        assert_eq!(ops.squares as usize, 3 * n * n + 3 * n);
        assert_eq!(pre.squares as usize, 3 * n * n);
    }
}
