//! Convolutions / correlations: direct (eq. 10/12) vs square-based
//! (eq. 11/13–14), real and complex (eq. 27–30, 44–47), with ledgers.
//!
//! All functions compute *valid-mode correlation* (the paper does not
//! distinguish convolution from correlation, §5).

use crate::arith::complex::{cmul_direct, Complex};

use super::counts::OpCounts;
use super::matrix::Matrix;

/// Direct 1-D correlation (eq. 10): y_k = Σ_i w_i·x_{i+k}.
pub fn conv1d_direct(w: &[i64], x: &[i64]) -> (Vec<i64>, OpCounts) {
    let n = w.len();
    assert!(x.len() >= n, "signal shorter than kernel");
    let mut ops = OpCounts::ZERO;
    let y = (0..=x.len() - n)
        .map(|k| {
            (0..n)
                .map(|i| {
                    ops.mult();
                    ops.add();
                    w[i] * x[i + k]
                })
                .sum()
        })
        .collect();
    (y, ops)
}

/// Square-based 1-D correlation (eq. 11, the Fig. 8 engine):
/// `y_k = ½(Σ_i (w_i+x_{i+k})² − Σ_i x_{i+k}² + Sw)`.
///
/// The per-sample `x²` is computed **once** per input sample and shared by
/// every window it participates in — the Fig. 8 dataflow — so the steady-
/// state cost is N+1 squares per output against N multiplications.
pub fn conv1d_square(w: &[i64], x: &[i64]) -> (Vec<i64>, OpCounts) {
    let n = w.len();
    assert!(x.len() >= n);
    let mut ops = OpCounts::ZERO;

    // Sw = −Σ w² — pre-computable (constant kernel), still ledgered
    let sw: i64 = -w
        .iter()
        .map(|&v| {
            ops.square();
            ops.add();
            v * v
        })
        .sum::<i64>();

    // per-sample squares, one each (shared across windows)
    let x2: Vec<i64> = x
        .iter()
        .map(|&v| {
            ops.square();
            v * v
        })
        .collect();

    let y = (0..=x.len() - n)
        .map(|k| {
            let mut acc = sw;
            ops.add();
            for i in 0..n {
                let s = w[i] + x[i + k];
                acc += s * s - x2[i + k];
                ops.square();
                ops.add_n(3);
            }
            ops.shift();
            acc >> 1
        })
        .collect();
    (y, ops)
}

/// Direct 2-D valid correlation (eq. 12).
pub fn conv2d_direct(w: &Matrix<i64>, x: &Matrix<i64>) -> (Matrix<i64>, OpCounts) {
    let (kh, kw) = (w.rows, w.cols);
    assert!(x.rows >= kh && x.cols >= kw);
    let mut ops = OpCounts::ZERO;
    let out = Matrix::from_fn(x.rows - kh + 1, x.cols - kw + 1, |h, k| {
        let mut acc = 0;
        for i in 0..kh {
            for j in 0..kw {
                acc += w.get(i, j) * x.get(h + i, k + j);
                ops.mult();
                ops.add();
            }
        }
        acc
    });
    (out, ops)
}

/// Square-based 2-D correlation (eq. 13/14): per-sample x² shared across
/// every kernel placement covering it (§5.1).
pub fn conv2d_square(w: &Matrix<i64>, x: &Matrix<i64>) -> (Matrix<i64>, OpCounts) {
    let (kh, kw) = (w.rows, w.cols);
    assert!(x.rows >= kh && x.cols >= kw);
    let mut ops = OpCounts::ZERO;

    let sw: i64 = -(0..kh)
        .flat_map(|i| (0..kw).map(move |j| (i, j)))
        .map(|(i, j)| {
            ops.square();
            ops.add();
            let v = w.get(i, j);
            v * v
        })
        .sum::<i64>();

    // one square per input sample, shared (§5.1)
    let mut x2 = Matrix::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        for j in 0..x.cols {
            let v = x.get(i, j);
            x2.set(i, j, v * v);
            ops.square();
        }
    }

    let out = Matrix::from_fn(x.rows - kh + 1, x.cols - kw + 1, |h, k| {
        let mut acc = sw;
        ops.add();
        for i in 0..kh {
            for j in 0..kw {
                let s = w.get(i, j) + x.get(h + i, k + j);
                acc += s * s - x2.get(h + i, k + j);
                ops.square();
                ops.add_n(3);
            }
        }
        ops.shift();
        acc >> 1
    });
    (out, ops)
}

/// Direct complex correlation (eq. 27).
pub fn cconv1d_direct(
    w: &[Complex<i64>],
    x: &[Complex<i64>],
) -> (Vec<Complex<i64>>, OpCounts) {
    let n = w.len();
    assert!(x.len() >= n);
    let mut ops = OpCounts::ZERO;
    let y = (0..=x.len() - n)
        .map(|k| {
            let mut acc = Complex::ZERO;
            for i in 0..n {
                acc += cmul_direct(w[i], x[i + k]);
                ops.mults += 4;
                ops.add_n(4);
            }
            acc
        })
        .collect();
    (y, ops)
}

/// Complex correlation with the 4-square CPM (eq. 28/29, Fig. 11).
pub fn cconv1d_cpm(
    w: &[Complex<i64>],
    x: &[Complex<i64>],
) -> (Vec<Complex<i64>>, OpCounts) {
    let n = w.len();
    assert!(x.len() >= n);
    let mut ops = OpCounts::ZERO;

    // Sw = −Σ (c² + s²)  (eq. 30)
    let sw: i64 = -w
        .iter()
        .map(|v| {
            ops.squares += 2;
            ops.add_n(2);
            v.re * v.re + v.im * v.im
        })
        .sum::<i64>();

    // per-sample energy x²+y², one pair of squares per sample, shared
    let e: Vec<i64> = x
        .iter()
        .map(|v| {
            ops.squares += 2;
            ops.add();
            v.re * v.re + v.im * v.im
        })
        .collect();

    let y = (0..=x.len() - n)
        .map(|k| {
            let (mut re, mut im) = (sw, sw);
            ops.add_n(2);
            for i in 0..n {
                let wv = w[i];
                let xv = x[i + k];
                let t1 = wv.re + xv.re;
                let t2 = wv.im - xv.im;
                let t3 = wv.im + xv.re;
                let t4 = wv.re + xv.im;
                re += t1 * t1 + t2 * t2 - e[i + k];
                im += t3 * t3 + t4 * t4 - e[i + k];
                ops.squares += 4;
                ops.add_n(10);
            }
            ops.shifts += 2;
            Complex::new(re >> 1, im >> 1)
        })
        .collect();
    (y, ops)
}

/// Complex correlation with the 3-square CPM3 (eq. 45/46, Fig. 14).
pub fn cconv1d_cpm3(
    w: &[Complex<i64>],
    x: &[Complex<i64>],
) -> (Vec<Complex<i64>>, OpCounts) {
    let n = w.len();
    assert!(x.len() >= n);
    let mut ops = OpCounts::ZERO;

    // eq. (47): Sw = Σ(−c² + (c+s)²) + j·Σ(−c² − (s−c)²)
    let (mut sw_re, mut sw_im) = (0i64, 0i64);
    for v in w {
        let c2 = v.re * v.re;
        let cs = v.re + v.im;
        let sc = v.im - v.re;
        sw_re += -c2 + cs * cs;
        sw_im += -c2 - sc * sc;
        ops.squares += 3;
        ops.add_n(6);
    }

    // common per-sample terms (−(x+y)²+y²) and (−(x+y)²−x²): 3 squares per
    // sample — (x+y)², x², y² — shared across windows
    let mut com_re = Vec::with_capacity(x.len());
    let mut com_im = Vec::with_capacity(x.len());
    for v in x {
        let xy = v.re + v.im;
        let xy2 = xy * xy;
        com_re.push(-xy2 + v.im * v.im);
        com_im.push(-xy2 - v.re * v.re);
        ops.squares += 3;
        ops.add_n(5);
    }

    let y = (0..=x.len() - n)
        .map(|k| {
            let (mut re, mut im) = (sw_re, sw_im);
            for i in 0..n {
                let wv = w[i];
                let xv = x[i + k];
                let t = wv.re + xv.re + xv.im; // c + x + y — shared square
                let t = t * t;
                let u = xv.im + wv.re + wv.im; // y + c + s
                let v2 = xv.re + wv.im - wv.re; // x + s − c
                re += t - u * u + com_re[i + k];
                im += t + v2 * v2 + com_im[i + k];
                ops.squares += 3;
                ops.add_n(10);
            }
            ops.shifts += 2;
            Complex::new(re >> 1, im >> 1)
        })
        .collect();
    (y, ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Rng};

    #[test]
    fn conv1d_square_exact() {
        forall(
            20,
            80,
            |rng, size| {
                let n = rng.usize_in(1, (size + 1).min(12));
                let l = n + rng.usize_in(0, 40);
                (rng.vec_i64(n, -500, 500), rng.vec_i64(l, -500, 500))
            },
            |(w, x)| {
                let (d, _) = conv1d_direct(w, x);
                let (s, _) = conv1d_square(w, x);
                if d == s { Ok(()) } else { Err(format!("n={} l={}", w.len(), x.len())) }
            },
        );
    }

    #[test]
    fn conv1d_ledger_steady_state() {
        // N-tap kernel over L samples: direct = N·K mults; square =
        // K·N window squares + L sample squares + N kernel squares
        let mut rng = Rng::new(21);
        let (n, l) = (16usize, 128usize);
        let w = rng.vec_i64(n, -100, 100);
        let x = rng.vec_i64(l, -100, 100);
        let k = (l - n + 1) as u64;
        let (_, d) = conv1d_direct(&w, &x);
        let (_, s) = conv1d_square(&w, &x);
        assert_eq!(d.mults, n as u64 * k);
        assert_eq!(s.mults, 0);
        assert_eq!(s.squares, n as u64 * k + l as u64 + n as u64);
        // per-output steady state → N + 1 squares vs N mults (§5)
        let per_out = s.squares as f64 / k as f64;
        assert!(per_out < (n as f64 + 1.0) + 0.3, "per_out={per_out}");
    }

    #[test]
    fn conv2d_square_exact() {
        let mut rng = Rng::new(22);
        for _ in 0..20 {
            let (kh, kw) = (rng.usize_in(1, 5), rng.usize_in(1, 5));
            let (h, w_) = (kh + rng.usize_in(0, 8), kw + rng.usize_in(0, 8));
            let ker = Matrix::random(&mut rng, kh, kw, -200, 200);
            let x = Matrix::random(&mut rng, h, w_, -200, 200);
            let (d, _) = conv2d_direct(&ker, &x);
            let (s, _) = conv2d_square(&ker, &x);
            assert_eq!(d, s);
        }
    }

    #[test]
    fn conv2d_ledger() {
        let mut rng = Rng::new(23);
        let ker = Matrix::random(&mut rng, 3, 3, -50, 50);
        let x = Matrix::random(&mut rng, 10, 10, -50, 50);
        let (_, d) = conv2d_direct(&ker, &x);
        let (_, s) = conv2d_square(&ker, &x);
        assert_eq!(d.mults, 9 * 8 * 8);
        assert_eq!(s.squares, 9 * 8 * 8 + 100 + 9); // window + shared x² + Sw
    }

    fn rand_cvec(rng: &mut Rng, n: usize, lim: i64) -> Vec<Complex<i64>> {
        (0..n)
            .map(|_| Complex::new(rng.i64_in(-lim, lim), rng.i64_in(-lim, lim)))
            .collect()
    }

    #[test]
    fn complex_convs_exact() {
        let mut rng = Rng::new(24);
        for _ in 0..30 {
            let n = rng.usize_in(1, 10);
            let l = n + rng.usize_in(0, 30);
            let w = rand_cvec(&mut rng, n, 300);
            let x = rand_cvec(&mut rng, l, 300);
            let (d, _) = cconv1d_direct(&w, &x);
            let (c4, _) = cconv1d_cpm(&w, &x);
            let (c3, _) = cconv1d_cpm3(&w, &x);
            assert_eq!(d, c4);
            assert_eq!(d, c3);
        }
    }

    #[test]
    fn complex_conv_ledgers() {
        let mut rng = Rng::new(25);
        let (n, l) = (8usize, 64usize);
        let w = rand_cvec(&mut rng, n, 100);
        let x = rand_cvec(&mut rng, l, 100);
        let k = (l - n + 1) as u64;
        let (_, c4) = cconv1d_cpm(&w, &x);
        let (_, c3) = cconv1d_cpm3(&w, &x);
        // CPM: 4 per tap·output + 2 per sample + 2 per tap
        assert_eq!(c4.squares, 4 * n as u64 * k + 2 * l as u64 + 2 * n as u64);
        // CPM3: 3 per tap·output + 3 per sample + 3 per tap
        assert_eq!(c3.squares, 3 * n as u64 * k + 3 * l as u64 + 3 * n as u64);
    }
}
