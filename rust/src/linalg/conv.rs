//! Convolutions / correlations: direct (eq. 10/12) vs square-based
//! (eq. 11/13–14), real and complex (eq. 27–30, 44–47), with ledgers.
//!
//! All functions compute *valid-mode correlation* (the paper does not
//! distinguish convolution from correlation, §5).

use crate::arith::complex::Complex;

use super::counts::OpCounts;
use super::engine::kernels;
use super::engine::spec::ConvSpec;
use super::engine::SquareScalar;
use super::matrix::Matrix;
use super::LinalgError;

/// Validated output shape of a valid-mode 2-D correlation: `kh×kw` kernel
/// over an `in_h×in_w` input — the stride-1, unpadded special case of
/// [`ConvSpec::output_shape`], which is the single place the output-size
/// arithmetic happens. A kernel that cannot be placed (or an empty
/// operand) is a typed [`LinalgError`] everywhere — reference stack and
/// engine lowering alike — never a panic or a silent `usize` underflow,
/// and the error reports the full stride/padding/dilation geometry.
pub fn conv2d_output_shape(
    kh: usize,
    kw: usize,
    in_h: usize,
    in_w: usize,
) -> Result<(usize, usize), LinalgError> {
    ConvSpec::new(1, 1, kh, kw).output_shape(in_h, in_w)
}

/// Direct (multiplier) NCHW 2-D convolution reference: `batch` images of
/// `spec.in_channels` planes of `in_h×in_w` (flattened
/// `[image][channel][row][col]`), a flattened `[filter][channel][kh][kw]`
/// bank of `spec.bank_len()` weights, stride/zero-padding/dilation
/// honoured, output in the serving layout
/// `[image][filter][out_row][out_col]`.
///
/// Deliberately naive — the independently-written oracle the generalized
/// im2col lowering is property-tested against (so it shares *no* code
/// with the engine's patch extraction). Hoisted ledger: every tap of
/// every output is one multiply-add; taps that fall in the padding read
/// zero but still count, keeping the ledger a function of the shape
/// alone, exactly like the lowering's.
pub fn conv2d_nchw_direct<T: SquareScalar>(
    images: &[T],
    batch: usize,
    in_h: usize,
    in_w: usize,
    filters: &[T],
    spec: &ConvSpec,
) -> Result<(Vec<T>, OpCounts), LinalgError> {
    let (out_h, out_w) = spec.output_shape(in_h, in_w)?;
    if batch == 0 {
        return Err(LinalgError::EmptyInput { what: "image batch" });
    }
    if images.len() != batch * spec.image_len(in_h, in_w) {
        return Err(LinalgError::ShapeMismatch {
            what: "image batch buffer",
            expected: (batch, spec.image_len(in_h, in_w)),
            got: (1, images.len()),
        });
    }
    if filters.len() != spec.bank_len() {
        return Err(LinalgError::ShapeMismatch {
            what: "filter bank buffer",
            expected: (spec.out_channels, spec.taps()),
            got: (1, filters.len()),
        });
    }
    let taps = spec.taps();
    let plane = in_h * in_w;
    let k_out = out_h * out_w;
    let mut out = vec![T::default(); batch * spec.out_channels * k_out];
    for b in 0..batch {
        let img = &images[b * spec.in_channels * plane..][..spec.in_channels * plane];
        for f in 0..spec.out_channels {
            let ker = &filters[f * taps..][..taps];
            let dst = &mut out[(b * spec.out_channels + f) * k_out..][..k_out];
            for oh in 0..out_h {
                for ow in 0..out_w {
                    let mut acc = T::default();
                    for c in 0..spec.in_channels {
                        let chan = &img[c * plane..][..plane];
                        for i in 0..spec.kernel_h {
                            for j in 0..spec.kernel_w {
                                let ih = oh * spec.stride_h + i * spec.dilation_h;
                                let iw = ow * spec.stride_w + j * spec.dilation_w;
                                let x = if ih < spec.pad_h
                                    || iw < spec.pad_w
                                    || ih - spec.pad_h >= in_h
                                    || iw - spec.pad_w >= in_w
                                {
                                    T::default()
                                } else {
                                    chan[(ih - spec.pad_h) * in_w + (iw - spec.pad_w)]
                                };
                                acc += ker[(c * spec.kernel_h + i) * spec.kernel_w + j] * x;
                            }
                        }
                    }
                    dst[oh * out_w + ow] = acc;
                }
            }
        }
    }
    let total = (batch * spec.out_channels * k_out * taps) as u64;
    Ok((out, OpCounts { mults: total, adds: total, ..OpCounts::ZERO }))
}

/// Direct 1-D correlation (eq. 10): y_k = Σ_i w_i·x_{i+k}.
///
/// Tap-major row-sliced accumulation (each tap streams over a contiguous
/// signal slice); ledger hoisted — N·K mults/adds, asserted equal to
/// per-element counting by `tests::hoisted_ledgers_equal_per_element`.
pub fn conv1d_direct(w: &[i64], x: &[i64]) -> (Vec<i64>, OpCounts) {
    let n = w.len();
    assert!(x.len() >= n, "signal shorter than kernel");
    let k_out = x.len() - n + 1;
    let mut y = vec![0i64; k_out];
    for (i, &wi) in w.iter().enumerate() {
        kernels::mul_acc_row(&mut y, wi, &x[i..i + k_out]);
    }
    let ops = OpCounts {
        mults: (n * k_out) as u64,
        adds: (n * k_out) as u64,
        ..OpCounts::ZERO
    };
    (y, ops)
}

/// Square-based 1-D correlation (eq. 11, the Fig. 8 engine):
/// `y_k = ½(Σ_i (w_i+x_{i+k})² − Σ_i x_{i+k}² + Sw)`.
///
/// The per-sample `x²` is computed **once** per input sample and shared by
/// every window it participates in — the Fig. 8 dataflow — so the steady-
/// state cost is N+1 squares per output against N multiplications. The
/// window accumulation is tap-major through the engine's fused
/// `(s+x)² − x²` row kernel; the ledger is hoisted out of the loops.
pub fn conv1d_square(w: &[i64], x: &[i64]) -> (Vec<i64>, OpCounts) {
    let n = w.len();
    assert!(x.len() >= n);
    let l = x.len();
    let k_out = l - n + 1;

    // Sw = −Σ w² — pre-computable (constant kernel), still ledgered
    let sw: i64 = -w.iter().map(|&v| v * v).sum::<i64>();

    // per-sample squares, one each (shared across windows)
    let x2: Vec<i64> = x.iter().map(|&v| v * v).collect();

    // seed every output with Sw, then accumulate tap-major: for tap i the
    // window term (w_i + x_{i+k})² − x²_{i+k} is one contiguous row sweep
    let mut y = vec![sw; k_out];
    for (i, &wi) in w.iter().enumerate() {
        kernels::sq_sub_acc_row(&mut y, wi, &x[i..i + k_out], &x2[i..i + k_out]);
    }
    for v in y.iter_mut() {
        *v >>= 1; // the trailing exact ÷2 of eq. (11)
    }

    // hoisted ledger ≡ per-element counting (asserted by tests):
    // Sw: N squares + N adds; shared x²: L squares; window: N·K squares,
    // 3 adds each, plus the per-output seed add and final shift
    let (nu, lu, ku) = (n as u64, l as u64, k_out as u64);
    let ops = OpCounts {
        mults: 0,
        squares: nu + lu + nu * ku,
        adds: nu + ku + 3 * nu * ku,
        shifts: ku,
    };
    (y, ops)
}

/// Direct 2-D valid correlation (eq. 12), tap-major over contiguous
/// output rows; hoisted ledger. Malformed shapes (kernel larger than the
/// input, empty operands) are a typed [`LinalgError`].
pub fn conv2d_direct(
    w: &Matrix<i64>,
    x: &Matrix<i64>,
) -> Result<(Matrix<i64>, OpCounts), LinalgError> {
    let (kh, kw) = (w.rows, w.cols);
    let (out_h, out_w) = conv2d_output_shape(kh, kw, x.rows, x.cols)?;
    let mut out = Matrix::zeros(out_h, out_w);
    for h in 0..out_h {
        let out_row = &mut out.data_mut()[h * out_w..(h + 1) * out_w];
        for i in 0..kh {
            let w_row = w.row(i);
            let x_row = x.row(h + i);
            for (j, &wij) in w_row.iter().enumerate() {
                kernels::mul_acc_row(out_row, wij, &x_row[j..j + out_w]);
            }
        }
    }
    let taps = (kh * kw * out_h * out_w) as u64;
    let ops = OpCounts { mults: taps, adds: taps, ..OpCounts::ZERO };
    Ok((out, ops))
}

/// Square-based 2-D correlation (eq. 13/14): per-sample x² shared across
/// every kernel placement covering it (§5.1). Tap-major: each kernel
/// weight sweeps one contiguous output row through the fused
/// `(s+x)² − x²` engine kernel; the ledger is hoisted. Malformed shapes
/// are a typed [`LinalgError`], same as [`conv2d_direct`].
pub fn conv2d_square(
    w: &Matrix<i64>,
    x: &Matrix<i64>,
) -> Result<(Matrix<i64>, OpCounts), LinalgError> {
    let (kh, kw) = (w.rows, w.cols);
    let (out_h, out_w) = conv2d_output_shape(kh, kw, x.rows, x.cols)?;

    // Sw = −Σ w² over the flat kernel
    let sw: i64 = -w.data().iter().map(|&v| v * v).sum::<i64>();

    // one square per input sample, shared (§5.1)
    let x2 = x.map(|v| v * v);

    let mut out = Matrix::zeros(out_h, out_w);
    for h in 0..out_h {
        let out_row = &mut out.data_mut()[h * out_w..(h + 1) * out_w];
        for v in out_row.iter_mut() {
            *v = sw;
        }
        for i in 0..kh {
            let w_row = w.row(i);
            let x_row = x.row(h + i);
            let x2_row = x2.row(h + i);
            for (j, &wij) in w_row.iter().enumerate() {
                kernels::sq_sub_acc_row(
                    out_row,
                    wij,
                    &x_row[j..j + out_w],
                    &x2_row[j..j + out_w],
                );
            }
        }
        for v in out_row.iter_mut() {
            *v >>= 1;
        }
    }

    // hoisted ledger ≡ per-element counting (asserted by tests)
    let t = (kh * kw) as u64; // taps
    let l = (x.rows * x.cols) as u64; // shared sample squares
    let k = (out_h * out_w) as u64; // outputs
    let ops = OpCounts {
        mults: 0,
        squares: t + l + t * k,
        adds: t + k + 3 * t * k,
        shifts: k,
    };
    Ok((out, ops))
}

/// Direct complex correlation (eq. 27), tap-major with a hoisted ledger.
pub fn cconv1d_direct(
    w: &[Complex<i64>],
    x: &[Complex<i64>],
) -> (Vec<Complex<i64>>, OpCounts) {
    let n = w.len();
    assert!(x.len() >= n);
    let k_out = x.len() - n + 1;
    let mut y = vec![Complex::ZERO; k_out];
    for (i, &wi) in w.iter().enumerate() {
        kernels::cmul_acc_crow(&mut y, wi, &x[i..i + k_out]);
    }
    let nk = (n * k_out) as u64;
    let ops = OpCounts { mults: 4 * nk, adds: 4 * nk, ..OpCounts::ZERO };
    (y, ops)
}

/// Complex correlation with the 4-square CPM (eq. 28/29, Fig. 11).
///
/// Planar (re/im) accumulators, tap-major through the engine's CPM
/// convolution row kernel; the per-sample energy `x²+y²` is computed once
/// and shared by every window (Fig. 11 dataflow). Hoisted ledger.
pub fn cconv1d_cpm(
    w: &[Complex<i64>],
    x: &[Complex<i64>],
) -> (Vec<Complex<i64>>, OpCounts) {
    let n = w.len();
    assert!(x.len() >= n);
    let l = x.len();
    let k_out = l - n + 1;

    // Sw = −Σ (c² + s²)  (eq. 30)
    let sw: i64 = -w.iter().map(|v| v.re * v.re + v.im * v.im).sum::<i64>();

    // per-sample energy x²+y², one pair of squares per sample, shared
    let e: Vec<i64> = x.iter().map(|v| v.re * v.re + v.im * v.im).collect();

    let mut re = vec![sw; k_out];
    let mut im = vec![sw; k_out];
    for (i, &wi) in w.iter().enumerate() {
        kernels::cpm_conv_acc_rows(&mut re, &mut im, wi, &x[i..i + k_out], &e[i..i + k_out]);
    }
    let y = re
        .into_iter()
        .zip(im)
        .map(|(r, i)| Complex::new(r >> 1, i >> 1))
        .collect();

    // hoisted ledger ≡ per-element counting (asserted by tests):
    // Sw 2N sq + 2N add; energy 2L sq + L add; window 4 sq + 10 add per
    // tap·output, 2 seed adds and 2 shifts per output
    let (nu, lu, ku) = (n as u64, l as u64, k_out as u64);
    let ops = OpCounts {
        mults: 0,
        squares: 2 * nu + 2 * lu + 4 * nu * ku,
        adds: 2 * nu + lu + 2 * ku + 10 * nu * ku,
        shifts: 2 * ku,
    };
    (y, ops)
}

/// Complex correlation with the 3-square CPM3 (eq. 45/46, Fig. 14).
///
/// Planar accumulators, tap-major through the engine's CPM3 convolution
/// row kernel; the three per-sample common squares are computed once and
/// shared across windows. Hoisted ledger.
pub fn cconv1d_cpm3(
    w: &[Complex<i64>],
    x: &[Complex<i64>],
) -> (Vec<Complex<i64>>, OpCounts) {
    let n = w.len();
    assert!(x.len() >= n);
    let l = x.len();
    let k_out = l - n + 1;

    // eq. (47): Sw = Σ(−c² + (c+s)²) + j·Σ(−c² − (s−c)²)
    let (mut sw_re, mut sw_im) = (0i64, 0i64);
    for v in w {
        let c2 = v.re * v.re;
        let cs = v.re + v.im;
        let sc = v.im - v.re;
        sw_re += -c2 + cs * cs;
        sw_im += -c2 - sc * sc;
    }

    // common per-sample terms (−(x+y)²+y²) and (−(x+y)²−x²): 3 squares per
    // sample — (x+y)², x², y² — shared across windows
    let mut com_re = Vec::with_capacity(l);
    let mut com_im = Vec::with_capacity(l);
    for v in x {
        let xy = v.re + v.im;
        let xy2 = xy * xy;
        com_re.push(-xy2 + v.im * v.im);
        com_im.push(-xy2 - v.re * v.re);
    }

    let mut re = vec![sw_re; k_out];
    let mut im = vec![sw_im; k_out];
    for (i, &wi) in w.iter().enumerate() {
        kernels::cpm3_conv_acc_rows(
            &mut re,
            &mut im,
            wi,
            &x[i..i + k_out],
            &com_re[i..i + k_out],
            &com_im[i..i + k_out],
        );
    }
    let y = re
        .into_iter()
        .zip(im)
        .map(|(r, i)| Complex::new(r >> 1, i >> 1))
        .collect();

    // hoisted ledger ≡ per-element counting (asserted by tests):
    // Sw 3N sq + 6N add; common terms 3L sq + 5L add; window 3 sq + 10 add
    // per tap·output, 2 shifts per output
    let (nu, lu, ku) = (n as u64, l as u64, k_out as u64);
    let ops = OpCounts {
        mults: 0,
        squares: 3 * nu + 3 * lu + 3 * nu * ku,
        adds: 6 * nu + 5 * lu + 10 * nu * ku,
        shifts: 2 * ku,
    };
    (y, ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Rng};

    #[test]
    fn conv1d_square_exact() {
        forall(
            20,
            80,
            |rng, size| {
                let n = rng.usize_in(1, (size + 1).min(12));
                let l = n + rng.usize_in(0, 40);
                (rng.vec_i64(n, -500, 500), rng.vec_i64(l, -500, 500))
            },
            |(w, x)| {
                let (d, _) = conv1d_direct(w, x);
                let (s, _) = conv1d_square(w, x);
                if d == s { Ok(()) } else { Err(format!("n={} l={}", w.len(), x.len())) }
            },
        );
    }

    #[test]
    fn conv1d_ledger_steady_state() {
        // N-tap kernel over L samples: direct = N·K mults; square =
        // K·N window squares + L sample squares + N kernel squares
        let mut rng = Rng::new(21);
        let (n, l) = (16usize, 128usize);
        let w = rng.vec_i64(n, -100, 100);
        let x = rng.vec_i64(l, -100, 100);
        let k = (l - n + 1) as u64;
        let (_, d) = conv1d_direct(&w, &x);
        let (_, s) = conv1d_square(&w, &x);
        assert_eq!(d.mults, n as u64 * k);
        assert_eq!(s.mults, 0);
        assert_eq!(s.squares, n as u64 * k + l as u64 + n as u64);
        // per-output steady state → N + 1 squares vs N mults (§5)
        let per_out = s.squares as f64 / k as f64;
        assert!(per_out < (n as f64 + 1.0) + 0.3, "per_out={per_out}");
    }

    #[test]
    fn conv2d_square_exact() {
        let mut rng = Rng::new(22);
        for _ in 0..20 {
            let (kh, kw) = (rng.usize_in(1, 5), rng.usize_in(1, 5));
            let (h, w_) = (kh + rng.usize_in(0, 8), kw + rng.usize_in(0, 8));
            let ker = Matrix::random(&mut rng, kh, kw, -200, 200);
            let x = Matrix::random(&mut rng, h, w_, -200, 200);
            let (d, _) = conv2d_direct(&ker, &x).unwrap();
            let (s, _) = conv2d_square(&ker, &x).unwrap();
            assert_eq!(d, s);
        }
    }

    #[test]
    fn conv2d_shape_errors_are_typed_not_panics() {
        use super::super::LinalgError;
        let ker = Matrix::<i64>::zeros(5, 5);
        let img = Matrix::<i64>::zeros(3, 8);
        // kernel taller than the input: previously a panic (and, without
        // the assert, a usize underflow in out_h = x.rows - kh + 1); the
        // typed error now carries the (default) stride/pad/dilation too
        let want_err = LinalgError::KernelDoesNotFit {
            kh: 5,
            kw: 5,
            in_h: 3,
            in_w: 8,
            stride: (1, 1),
            pad: (0, 0),
            dilation: (1, 1),
        };
        assert_eq!(conv2d_direct(&ker, &img).unwrap_err(), want_err);
        assert_eq!(conv2d_square(&ker, &img).unwrap_err(), want_err);
        // empty input
        let empty = Matrix::<i64>::zeros(0, 4);
        let one = Matrix::<i64>::zeros(1, 1);
        assert_eq!(
            conv2d_direct(&one, &empty).unwrap_err(),
            LinalgError::EmptyInput { what: "input" }
        );
        // empty kernel
        let ek = Matrix::<i64>::zeros(0, 3);
        let x = Matrix::<i64>::zeros(4, 4);
        assert_eq!(
            conv2d_square(&ek, &x).unwrap_err(),
            LinalgError::EmptyInput { what: "kernel" }
        );
        // the validator itself, including the exactly-fitting boundary
        assert_eq!(conv2d_output_shape(3, 3, 3, 3), Ok((1, 1)));
        assert!(conv2d_output_shape(4, 3, 3, 3).is_err());
    }

    #[test]
    fn conv2d_ledger() {
        let mut rng = Rng::new(23);
        let ker = Matrix::random(&mut rng, 3, 3, -50, 50);
        let x = Matrix::random(&mut rng, 10, 10, -50, 50);
        let (_, d) = conv2d_direct(&ker, &x).unwrap();
        let (_, s) = conv2d_square(&ker, &x).unwrap();
        assert_eq!(d.mults, 9 * 8 * 8);
        assert_eq!(s.squares, 9 * 8 * 8 + 100 + 9); // window + shared x² + Sw
    }

    #[test]
    fn nchw_direct_single_channel_defaults_equal_conv2d_direct() {
        let mut rng = Rng::new(27);
        let (kh, kw, h, w) = (3usize, 2usize, 7usize, 9usize);
        let ker = Matrix::random(&mut rng, kh, kw, -60, 60);
        let img = Matrix::random(&mut rng, h, w, -60, 60);
        let spec = ConvSpec::new(1, 1, kh, kw);
        let (got, ops) =
            conv2d_nchw_direct(img.data(), 1, h, w, ker.data(), &spec).unwrap();
        let (want, want_ops) = conv2d_direct(&ker, &img).unwrap();
        assert_eq!(got, want.data());
        assert_eq!(ops, want_ops, "C=1 stride-1 pad-0 ledger must match");
    }

    #[test]
    fn nchw_direct_multi_channel_sums_per_channel_valid_convs() {
        // with stride 1 / pad 0, an NCHW conv is the per-channel valid
        // conv summed over channels — cross-check against conv2d_direct
        let mut rng = Rng::new(28);
        let spec = ConvSpec::new(3, 2, 2, 3);
        let (h, w) = (6usize, 8usize);
        let images = rng.vec_i64(spec.image_len(h, w), -40, 40);
        let filters = rng.vec_i64(spec.bank_len(), -40, 40);
        let (got, ops) = conv2d_nchw_direct(&images, 1, h, w, &filters, &spec).unwrap();
        let (out_h, out_w) = spec.output_shape(h, w).unwrap();
        let k_out = out_h * out_w;
        let plane = h * w;
        let khw = spec.kernel_h * spec.kernel_w;
        for f in 0..spec.out_channels {
            let mut want = Matrix::zeros(out_h, out_w);
            for c in 0..spec.in_channels {
                let ker = Matrix::from_vec(
                    spec.kernel_h,
                    spec.kernel_w,
                    filters[(f * spec.in_channels + c) * khw..][..khw].to_vec(),
                );
                let img =
                    Matrix::from_vec(h, w, images[c * plane..][..plane].to_vec());
                let (part, _) = conv2d_direct(&ker, &img).unwrap();
                for (acc, &v) in want.data_mut().iter_mut().zip(part.data()) {
                    *acc += v;
                }
            }
            assert_eq!(&got[f * k_out..(f + 1) * k_out], want.data(), "filter {f}");
        }
        // ledger: one multiply-add per tap per output
        let taps = (spec.taps() * spec.out_channels * k_out) as u64;
        assert_eq!(ops.mults, taps);
        assert_eq!(ops.adds, taps);
    }

    #[test]
    fn nchw_direct_padding_ring_is_zero_extended() {
        // a 1×1 input with pad 1 under a 3×3 kernel sees the sample once,
        // at the kernel centre — everything else reads padding zeros
        let spec = ConvSpec::new(1, 1, 3, 3).with_padding(1);
        let (got, _) = conv2d_nchw_direct(&[5i64], 1, 1, 1, &[1, 2, 3, 4, 7, 6, 8, 9, 10], &spec)
            .unwrap();
        assert_eq!(got, vec![5 * 7]);
    }

    #[test]
    fn nchw_direct_rejects_malformed_buffers() {
        let spec = ConvSpec::new(2, 1, 2, 2);
        assert_eq!(
            conv2d_nchw_direct(&[0i64; 8], 0, 2, 2, &[0; 8], &spec).unwrap_err(),
            LinalgError::EmptyInput { what: "image batch" }
        );
        assert!(matches!(
            conv2d_nchw_direct(&[0i64; 7], 1, 2, 2, &[0; 8], &spec).unwrap_err(),
            LinalgError::ShapeMismatch { what: "image batch buffer", .. }
        ));
        assert!(matches!(
            conv2d_nchw_direct(&[0i64; 8], 1, 2, 2, &[0; 7], &spec).unwrap_err(),
            LinalgError::ShapeMismatch { what: "filter bank buffer", .. }
        ));
        assert!(matches!(
            conv2d_nchw_direct(&[0i64; 2], 1, 1, 1, &[0; 8], &spec).unwrap_err(),
            LinalgError::KernelDoesNotFit { stride: (1, 1), pad: (0, 0), .. }
        ));
    }

    fn rand_cvec(rng: &mut Rng, n: usize, lim: i64) -> Vec<Complex<i64>> {
        (0..n)
            .map(|_| Complex::new(rng.i64_in(-lim, lim), rng.i64_in(-lim, lim)))
            .collect()
    }

    #[test]
    fn complex_convs_exact() {
        let mut rng = Rng::new(24);
        for _ in 0..30 {
            let n = rng.usize_in(1, 10);
            let l = n + rng.usize_in(0, 30);
            let w = rand_cvec(&mut rng, n, 300);
            let x = rand_cvec(&mut rng, l, 300);
            let (d, _) = cconv1d_direct(&w, &x);
            let (c4, _) = cconv1d_cpm(&w, &x);
            let (c3, _) = cconv1d_cpm3(&w, &x);
            assert_eq!(d, c4);
            assert_eq!(d, c3);
        }
    }

    /// Re-derive every conv ledger the way the seed tree did — one closure
    /// call per scalar operation — and assert the hoisted formulas are
    /// identical, field by field.
    #[test]
    fn hoisted_ledgers_equal_per_element() {
        fn conv1d_direct_ref(n: usize, l: usize) -> OpCounts {
            let mut ops = OpCounts::ZERO;
            for _k in 0..=(l - n) {
                for _i in 0..n {
                    ops.mult();
                    ops.add();
                }
            }
            ops
        }
        fn conv1d_square_ref(n: usize, l: usize) -> OpCounts {
            let mut ops = OpCounts::ZERO;
            for _ in 0..n {
                ops.square();
                ops.add();
            }
            for _ in 0..l {
                ops.square();
            }
            for _k in 0..=(l - n) {
                ops.add();
                for _i in 0..n {
                    ops.square();
                    ops.add_n(3);
                }
                ops.shift();
            }
            ops
        }
        fn conv2d_ref(kh: usize, kw: usize, h: usize, w: usize) -> (OpCounts, OpCounts) {
            let mut direct = OpCounts::ZERO;
            let mut square = OpCounts::ZERO;
            for _ in 0..kh * kw {
                square.square();
                square.add();
            }
            for _ in 0..h * w {
                square.square();
            }
            for _out in 0..(h - kh + 1) * (w - kw + 1) {
                square.add();
                for _tap in 0..kh * kw {
                    direct.mult();
                    direct.add();
                    square.square();
                    square.add_n(3);
                }
                square.shift();
            }
            (direct, square)
        }
        fn cconv_refs(n: usize, l: usize) -> (OpCounts, OpCounts, OpCounts) {
            let (mut direct, mut cpm, mut cpm3) =
                (OpCounts::ZERO, OpCounts::ZERO, OpCounts::ZERO);
            for _ in 0..n {
                cpm.squares += 2;
                cpm.add_n(2);
                cpm3.squares += 3;
                cpm3.add_n(6);
            }
            for _ in 0..l {
                cpm.squares += 2;
                cpm.add();
                cpm3.squares += 3;
                cpm3.add_n(5);
            }
            for _k in 0..=(l - n) {
                cpm.add_n(2);
                for _i in 0..n {
                    direct.mults += 4;
                    direct.add_n(4);
                    cpm.squares += 4;
                    cpm.add_n(10);
                    cpm3.squares += 3;
                    cpm3.add_n(10);
                }
                cpm.shifts += 2;
                cpm3.shifts += 2;
            }
            (direct, cpm, cpm3)
        }

        let mut rng = Rng::new(26);
        for (n, l) in [(1usize, 1usize), (3, 17), (16, 128)] {
            let w = rng.vec_i64(n, -50, 50);
            let x = rng.vec_i64(l, -50, 50);
            assert_eq!(conv1d_direct(&w, &x).1, conv1d_direct_ref(n, l), "direct {n}/{l}");
            assert_eq!(conv1d_square(&w, &x).1, conv1d_square_ref(n, l), "square {n}/{l}");

            let cw = rand_cvec(&mut rng, n, 50);
            let cx = rand_cvec(&mut rng, l, 50);
            let (dref, c4ref, c3ref) = cconv_refs(n, l);
            assert_eq!(cconv1d_direct(&cw, &cx).1, dref, "cdirect {n}/{l}");
            assert_eq!(cconv1d_cpm(&cw, &cx).1, c4ref, "cpm {n}/{l}");
            assert_eq!(cconv1d_cpm3(&cw, &cx).1, c3ref, "cpm3 {n}/{l}");
        }
        for (kh, kw, h, w_) in [(1usize, 1usize, 1usize, 1usize), (3, 2, 9, 11)] {
            let ker = Matrix::random(&mut rng, kh, kw, -30, 30);
            let x = Matrix::random(&mut rng, h, w_, -30, 30);
            let (dref, sref) = conv2d_ref(kh, kw, h, w_);
            assert_eq!(conv2d_direct(&ker, &x).unwrap().1, dref);
            assert_eq!(conv2d_square(&ker, &x).unwrap().1, sref);
        }
    }

    #[test]
    fn complex_conv_ledgers() {
        let mut rng = Rng::new(25);
        let (n, l) = (8usize, 64usize);
        let w = rand_cvec(&mut rng, n, 100);
        let x = rand_cvec(&mut rng, l, 100);
        let k = (l - n + 1) as u64;
        let (_, c4) = cconv1d_cpm(&w, &x);
        let (_, c3) = cconv1d_cpm3(&w, &x);
        // CPM: 4 per tap·output + 2 per sample + 2 per tap
        assert_eq!(c4.squares, 4 * n as u64 * k + 2 * l as u64 + 2 * n as u64);
        // CPM3: 3 per tap·output + 3 per sample + 3 per tap
        assert_eq!(c3.squares, 3 * n as u64 * k + 3 * l as u64 + 3 * n as u64);
    }
}
