//! Minimal dense row-major matrix used across the reference stack and the
//! simulators. First-party on purpose: the offline environment carries no
//! ndarray, and the library only needs predictable row-major storage.

use std::fmt;
use std::ops::{Index, IndexMut};

use crate::testkit::Rng;

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Matrix<T> {
    pub rows: usize,
    pub cols: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> Matrix<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![T::default(); rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> T {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Row slice (row-major ⇒ contiguous).
    #[inline]
    pub fn row(&self, i: usize) -> &[T] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<T> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    pub fn transpose(&self) -> Self {
        Self::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume the matrix and recover its storage — how the workspace
    /// path returns a checked-out buffer to its arena without copying
    /// (the inverse of [`Matrix::from_vec`]).
    pub fn into_data(self) -> Vec<T> {
        self.data
    }

    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Matrix<U> {
        Matrix::from_vec(self.rows, self.cols, self.data.iter().map(|&x| f(x)).collect())
    }
}

impl Matrix<i64> {
    /// Random matrix with entries in `[lo, hi]`.
    pub fn random(rng: &mut Rng, rows: usize, cols: usize, lo: i64, hi: i64) -> Self {
        Self::from_vec(rows, cols, rng.vec_i64(rows * cols, lo, hi))
    }
}

impl Matrix<f64> {
    pub fn random_normal(rng: &mut Rng, rows: usize, cols: usize) -> Self {
        Self::from_vec(rows, cols, rng.vec_normal(rows * cols))
    }

    pub fn max_abs_diff(&self, o: &Self) -> f64 {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        self.data
            .iter()
            .zip(&o.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl Matrix<f32> {
    pub fn random_normal_f32(rng: &mut Rng, rows: usize, cols: usize) -> Self {
        Self::from_vec(rows, cols, rng.vec_f32_normal(rows * cols))
    }
}

impl<T> Index<(usize, usize)> for Matrix<T> {
    type Output = T;
    fn index(&self, (i, j): (usize, usize)) -> &T {
        &self.data[i * self.cols + j]
    }
}

impl<T> IndexMut<(usize, usize)> for Matrix<T> {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut T {
        &mut self.data[i * self.cols + j]
    }
}

impl<T: fmt::Display> fmt::Display for Matrix<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for i in 0..self.rows {
            for j in 0..self.cols {
                write!(f, "{:>10} ", self.data[i * self.cols + j])?;
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 10 + j) as i64);
        assert_eq!(m.get(1, 2), 12);
        assert_eq!(m.row(1), &[10, 11, 12]);
        assert_eq!(m.col(2), vec![2, 12]);
        assert_eq!(m[(0, 1)], 1);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(4);
        let m = Matrix::random(&mut rng, 5, 7, -9, 9);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn map_preserves_shape() {
        let m = Matrix::from_fn(3, 2, |i, j| (i + j) as i64);
        let d = m.map(|x| x as f64 * 0.5);
        assert_eq!((d.rows, d.cols), (3, 2));
        assert_eq!(d.get(2, 1), 1.5);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        let _ = Matrix::from_vec(2, 2, vec![1i64, 2, 3]);
    }
}
