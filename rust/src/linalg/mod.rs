//! Op-counted linear-algebra reference stack.
//!
//! This is the software embodiment of the paper's math: every operation
//! (§3 matmul, §4 transforms, §5 convolutions, §6/§9 complex matmul,
//! §7/§10 complex transforms, §8/§11 complex convolutions) exists in a
//! *direct* (multiplier) form and a *square-based* form, and both report an
//! exact [`OpCounts`] ledger so the benches can regenerate the paper's
//! ratio claims (eq. 6, 20, 36) empirically instead of quoting formulas.
//!
//! Integer (`i64`) entry points are bit-exact (the hardware domain);
//! `f64`/`f32` entry points feed the numerical-error experiment E5.
//!
//! The performance-bearing implementation is [`engine`]: cache-blocked,
//! optionally multi-threaded square kernels with hoisted ledgers and a
//! precomputed-correction cache for constant weights. The reference
//! functions here delegate their hot loops to it.

pub mod complex;
pub mod conv;
pub mod counts;
pub mod engine;
pub mod error;
pub mod matmul;
pub mod qnn;
pub mod matrix;
pub mod transform;

pub use counts::OpCounts;
pub use engine::{EngineConfig, PreparedB, SquareScalar};
pub use matrix::Matrix;
