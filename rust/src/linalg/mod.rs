//! Op-counted linear-algebra reference stack.
//!
//! This is the software embodiment of the paper's math: every operation
//! (§3 matmul, §4 transforms, §5 convolutions, §6/§9 complex matmul,
//! §7/§10 complex transforms, §8/§11 complex convolutions) exists in a
//! *direct* (multiplier) form and a *square-based* form, and both report an
//! exact [`OpCounts`] ledger so the benches can regenerate the paper's
//! ratio claims (eq. 6, 20, 36) empirically instead of quoting formulas.
//!
//! Integer (`i64`) entry points are bit-exact (the hardware domain);
//! `f64`/`f32` entry points feed the numerical-error experiment E5.
//!
//! The performance-bearing implementation is [`engine`]: cache-blocked,
//! optionally multi-threaded square kernels with hoisted ledgers and a
//! precomputed-correction cache for constant weights. The reference
//! functions here delegate their hot loops to it.

pub mod complex;
pub mod conv;
pub mod counts;
pub mod engine;
pub mod error;
pub mod matmul;
pub mod qnn;
pub mod matrix;
pub mod transform;

pub use counts::OpCounts;
pub use engine::{ConvSpec, EngineConfig, EngineWorkspace, PreparedB, SquareScalar};
pub use matrix::Matrix;

/// Shape-validation errors for the fallible linalg entry points.
///
/// The reference stack historically `assert!`ed its preconditions; for the
/// serving-facing paths (2-D convolution and the engine lowering subsystem)
/// a malformed request must surface as an `Err` the coordinator can return
/// to the client, not a worker-killing panic — and never as silent
/// `usize` underflow in output-size arithmetic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LinalgError {
    /// an operand has a zero dimension where real work is required
    EmptyInput { what: &'static str },
    /// correlation needs at least one placement of the (dilated) kernel
    /// inside the (padded) input — reported with the full [`ConvSpec`]
    /// geometry so a stride/padding misconfiguration is actionable, not
    /// just the kernel-vs-image sizes
    KernelDoesNotFit {
        kh: usize,
        kw: usize,
        in_h: usize,
        in_w: usize,
        /// `(stride_h, stride_w)` of the failing spec (`(1, 1)` for the
        /// legacy valid-mode entry points)
        stride: (usize, usize),
        /// `(pad_h, pad_w)` of the failing spec
        pad: (usize, usize),
        /// `(dilation_h, dilation_w)` of the failing spec
        dilation: (usize, usize),
    },
    /// a [`ConvSpec`] field that must be positive is zero
    InvalidConvSpec { field: &'static str },
    /// `A·B` with `a.cols != b.rows`
    ContractionMismatch {
        left_cols: usize,
        right_rows: usize,
    },
    /// operands that must share a shape (planes, batch buffers) disagree
    ShapeMismatch {
        what: &'static str,
        expected: (usize, usize),
        got: (usize, usize),
    },
}

impl std::fmt::Display for LinalgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::EmptyInput { what } => write!(f, "empty {what}: every dimension must be non-zero"),
            Self::KernelDoesNotFit { kh, kw, in_h, in_w, stride, pad, dilation } => write!(
                f,
                "kernel {kh}x{kw} (dilation {}x{}) does not fit inside input \
                 {in_h}x{in_w} with padding {}x{} at stride {}x{} \
                 (correlation needs at least one kernel placement)",
                dilation.0, dilation.1, pad.0, pad.1, stride.0, stride.1
            ),
            Self::InvalidConvSpec { field } => {
                write!(f, "invalid ConvSpec: {field} must be positive")
            }
            Self::ContractionMismatch { left_cols, right_rows } => write!(
                f,
                "contraction mismatch: left operand has {left_cols} columns, \
                 right operand has {right_rows} rows"
            ),
            Self::ShapeMismatch { what, expected, got } => write!(
                f,
                "shape mismatch for {what}: expected {}x{}, got {}x{}",
                expected.0, expected.1, got.0, got.1
            ),
        }
    }
}

impl std::error::Error for LinalgError {}
