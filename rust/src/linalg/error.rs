//! Numerical-error characterisation of the square trick (experiment E5).
//!
//! The paper treats the rewrite as exact — true for integers, *not* for
//! floating point: `½((a+b)² − a² − b²)` cancels catastrophically when
//! `|ab| ≪ a² + b²`, and the accumulated `Sab + Sa + Sb` of eq. (4) sums
//! large positive and negative parts whose difference is the (small)
//! result. This module quantifies that against an f64 ground truth, because
//! a downstream user deciding between fp32 direct and fp32 square-based
//! matmul needs the honest number.

use super::matmul::{matmul_direct_f64, matmul_square_f32, matmul_square_f64};
use super::matrix::Matrix;
use crate::testkit::Rng;

/// Error statistics of one computation vs a reference.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ErrorStats {
    pub max_abs: f64,
    pub mean_abs: f64,
    pub rel_fro: f64,
}

impl ErrorStats {
    /// Compare `got` against `want` element-wise.
    pub fn compare(got: &[f64], want: &[f64]) -> Self {
        assert_eq!(got.len(), want.len());
        assert!(!got.is_empty());
        let mut max_abs = 0.0f64;
        let mut sum_abs = 0.0f64;
        let mut err_sq = 0.0f64;
        let mut ref_sq = 0.0f64;
        for (&g, &w) in got.iter().zip(want) {
            let e = (g - w).abs();
            max_abs = max_abs.max(e);
            sum_abs += e;
            err_sq += e * e;
            ref_sq += w * w;
        }
        Self {
            max_abs,
            mean_abs: sum_abs / got.len() as f64,
            rel_fro: (err_sq / ref_sq.max(f64::MIN_POSITIVE)).sqrt(),
        }
    }
}

/// One row of the E5 table: error of direct-f32, square-f32 and square-f64
/// matmul vs the f64 direct ground truth, for one (n, scale) setting.
#[derive(Debug, Clone, Copy)]
pub struct MatmulErrorRow {
    pub n: usize,
    /// operand magnitude scale (σ of the normal entries)
    pub scale: f64,
    pub direct_f32: ErrorStats,
    pub square_f32: ErrorStats,
    pub square_f64: ErrorStats,
    /// amplification = square_f32.rel_fro / direct_f32.rel_fro
    pub amplification: f64,
}

/// Run the E5 sweep for square n×n matmuls.
pub fn matmul_error_sweep(ns: &[usize], scales: &[f64], seed: u64) -> Vec<MatmulErrorRow> {
    let mut rows = Vec::new();
    for &n in ns {
        for &scale in scales {
            let mut rng = Rng::new(seed ^ (n as u64) << 8 ^ scale.to_bits());
            let a64 = Matrix::from_vec(
                n,
                n,
                rng.vec_normal(n * n).iter().map(|v| v * scale).collect(),
            );
            let b64 = Matrix::from_vec(
                n,
                n,
                rng.vec_normal(n * n).iter().map(|v| v * scale).collect(),
            );
            // ground truth in f64 direct
            let truth = matmul_direct_f64(&a64, &b64);

            let a32 = a64.map(|v| v as f32);
            let b32 = b64.map(|v| v as f32);
            let d32 = super::matmul::matmul_direct_f32(&a32, &b32);
            let s32 = matmul_square_f32(&a32, &b32);
            let s64 = matmul_square_f64(&a64, &b64);

            let t = truth.data();
            let row = MatmulErrorRow {
                n,
                scale,
                direct_f32: ErrorStats::compare(
                    &d32.data().iter().map(|&v| v as f64).collect::<Vec<_>>(),
                    t,
                ),
                square_f32: ErrorStats::compare(
                    &s32.data().iter().map(|&v| v as f64).collect::<Vec<_>>(),
                    t,
                ),
                square_f64: ErrorStats::compare(s64.data(), t),
                amplification: 0.0,
            };
            let amp = row.square_f32.rel_fro / row.direct_f32.rel_fro.max(f64::MIN_POSITIVE);
            rows.push(MatmulErrorRow { amplification: amp, ..row });
        }
    }
    rows
}

/// Worst-case scalar demonstration: the relative error of the f32 square
/// trick for `a·b` with `|a| ≫ |b|` grows like `a²/(ab)` ulps.
pub fn scalar_cancellation_demo(ratio: f64) -> (f64, f64) {
    let a = ratio as f32;
    let b = 1.0f32;
    let direct = (a as f64) * (b as f64);
    let s = a + b;
    let tricked = 0.5 * ((s * s) as f64 - (a * a) as f64 - (b * b) as f64)
        .max(f64::MIN_POSITIVE);
    let rel = ((tricked - direct) / direct).abs();
    (direct, rel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_identical_inputs_are_zero() {
        let v = vec![1.0, -2.0, 3.0];
        let s = ErrorStats::compare(&v, &v);
        assert_eq!(s.max_abs, 0.0);
        assert_eq!(s.rel_fro, 0.0);
    }

    #[test]
    fn stats_detect_known_error() {
        let got = vec![1.0, 2.0, 3.0];
        let want = vec![1.0, 2.0, 4.0];
        let s = ErrorStats::compare(&got, &want);
        assert_eq!(s.max_abs, 1.0);
        assert!((s.mean_abs - 1.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn square_f64_is_tight() {
        let rows = matmul_error_sweep(&[16, 32], &[1.0], 77);
        for r in rows {
            // f64 square trick vs f64 direct: both ~1e-14 territory
            assert!(r.square_f64.rel_fro < 1e-12, "{:?}", r.square_f64);
        }
    }

    #[test]
    fn f32_amplification_is_bounded_but_real() {
        let rows = matmul_error_sweep(&[32], &[1.0], 78);
        for r in rows {
            // square-f32 loses ~1 bit (amp ~2×) at unit scale; it must not
            // be catastrophically worse, nor mysteriously better than ~0.5×
            assert!(r.amplification > 0.5 && r.amplification < 64.0,
                    "amp={}", r.amplification);
        }
    }

    #[test]
    fn cancellation_grows_with_operand_ratio() {
        let (_, rel_small) = scalar_cancellation_demo(4.0);
        let (_, rel_big) = scalar_cancellation_demo(4096.0);
        assert!(rel_big > rel_small, "rel {rel_small} -> {rel_big}");
    }
}
