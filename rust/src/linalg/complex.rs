//! Complex matrix multiplication: direct (eq. 15/16), 3-mult (eq. 31),
//! CPM 4-square (eq. 17–19) and CPM3 3-square (eq. 32–35) — all with exact
//! operation ledgers for the eq. (20)/(36) ratio benches.

use crate::arith::complex::Complex;

use super::counts::OpCounts;
use super::engine::kernels;
use super::matrix::Matrix;

pub type CMatrix = Matrix<Complex<i64>>;

/// Direct complex matmul (eq. 15/16): M·N·P complex mults = 4·M·N·P real
/// mults. The ledger counts *real* operations and is hoisted; the hot
/// loop is row-sliced i-k-j through the engine's complex row kernel.
pub fn cmatmul_direct(x: &CMatrix, y: &CMatrix) -> (CMatrix, OpCounts) {
    assert_eq!(x.cols, y.rows);
    let (m, n, p) = (x.rows, x.cols, y.cols);
    let mut z = CMatrix::zeros(m, p);
    for h in 0..m {
        let z_row = &mut z.data_mut()[h * p..(h + 1) * p];
        let x_row = x.row(h);
        for (i, &xv) in x_row.iter().enumerate() {
            kernels::cmul_acc_crow(z_row, xv, y.row(i));
        }
    }
    let mnp = (m * n * p) as u64;
    let ops = OpCounts { mults: 4 * mnp, adds: 4 * mnp, ..OpCounts::ZERO };
    (z, ops)
}

/// 3-real-mult complex matmul baseline (eq. 31, Karatsuba-style),
/// row-sliced i-k-j with a hoisted ledger.
pub fn cmatmul_3mult(x: &CMatrix, y: &CMatrix) -> (CMatrix, OpCounts) {
    assert_eq!(x.cols, y.rows);
    let (m, n, p) = (x.rows, x.cols, y.cols);
    let mut z = CMatrix::zeros(m, p);
    for h in 0..m {
        let z_row = &mut z.data_mut()[h * p..(h + 1) * p];
        let x_row = x.row(h);
        for (i, &xv) in x_row.iter().enumerate() {
            kernels::cmul3_acc_crow(z_row, xv, y.row(i));
        }
    }
    let mnp = (m * n * p) as u64;
    let ops = OpCounts { mults: 3 * mnp, adds: 7 * mnp, ..OpCounts::ZERO };
    (z, ops)
}

/// CPM complex matmul (eq. 17–19): 4 squares per complex product plus the
/// reusable `Sx_h`/`Sy_k` corrections (2·M·N + 2·N·P squares).
///
/// Row-sliced i-k-j: each output row is seeded with its rank-1 correction
/// and then swept tap-major by the engine's CPM row kernel. Hoisted ledger.
pub fn cmatmul_cpm(x: &CMatrix, y: &CMatrix) -> (CMatrix, OpCounts) {
    assert_eq!(x.cols, y.rows);
    let (m, n, p) = (x.rows, x.cols, y.cols);

    // Sx_h = −Σ_i (a² + b²)  — 2 squares per element of X
    let sx: Vec<i64> = (0..m)
        .map(|h| -x.row(h).iter().map(|v| v.re * v.re + v.im * v.im).sum::<i64>())
        .collect();
    // Sy_k = −Σ_i (c² + s²), accumulated row-sweep (contiguous access)
    let mut sy = vec![0i64; p];
    for i in 0..y.rows {
        for (s, v) in sy.iter_mut().zip(y.row(i)) {
            *s += v.re * v.re + v.im * v.im;
        }
    }
    for s in sy.iter_mut() {
        *s = -*s;
    }

    let mut z = CMatrix::zeros(m, p);
    for h in 0..m {
        let z_row = &mut z.data_mut()[h * p..(h + 1) * p];
        let sxh = sx[h];
        for (zv, &syk) in z_row.iter_mut().zip(&sy) {
            let corr = sxh + syk;
            *zv = Complex::new(corr, corr);
        }
        let x_row = x.row(h);
        for (i, &xv) in x_row.iter().enumerate() {
            kernels::cpm_acc_crow(z_row, xv, y.row(i));
        }
        for zv in z_row.iter_mut() {
            zv.re >>= 1;
            zv.im >>= 1;
        }
    }

    // hoisted ledger ≡ per-element counting (asserted by tests)
    let (mu, nu, pu) = (m as u64, n as u64, p as u64);
    let ops = OpCounts {
        mults: 0,
        squares: 2 * mu * nu + 2 * nu * pu + 4 * mu * nu * pu,
        adds: 2 * mu * nu + 2 * nu * pu + mu * pu + 8 * mu * nu * pu,
        shifts: 2 * mu * pu,
    };
    (z, ops)
}

/// CPM3 complex matmul (eq. 32–35): 3 squares per complex product — the
/// `(c+a+b)²` term is computed once and feeds both accumulators — plus the
/// reusable `Sab/Sba/Scs/Ssc` corrections (3·M·N + 3·N·P squares).
///
/// Row-sliced i-k-j through the engine's CPM3 row kernel; hoisted ledger.
pub fn cmatmul_cpm3(x: &CMatrix, y: &CMatrix) -> (CMatrix, OpCounts) {
    assert_eq!(x.cols, y.rows);
    let (m, n, p) = (x.rows, x.cols, y.cols);

    // eq. (33)/(35) row corrections: (a+b)², a², b² → 3 squares per element
    let mut sab = vec![0i64; m];
    let mut sba = vec![0i64; m];
    for h in 0..m {
        for v in x.row(h) {
            let ab = v.re + v.im;
            let ab2 = ab * ab;
            sab[h] += -ab2 + v.im * v.im;
            sba[h] += -ab2 - v.re * v.re;
        }
    }
    // eq. (33)/(35) column corrections: c², (c+s)², (s−c)² → 3 squares,
    // accumulated row-sweep (contiguous access)
    let mut scs = vec![0i64; p];
    let mut ssc = vec![0i64; p];
    for i in 0..y.rows {
        for ((cs_acc, sc_acc), v) in scs.iter_mut().zip(ssc.iter_mut()).zip(y.row(i)) {
            let c2 = v.re * v.re;
            let cs = v.re + v.im;
            let sc = v.im - v.re;
            *cs_acc += -c2 + cs * cs;
            *sc_acc += -c2 - sc * sc;
        }
    }

    let mut z = CMatrix::zeros(m, p);
    for h in 0..m {
        let z_row = &mut z.data_mut()[h * p..(h + 1) * p];
        for ((zv, &cs), &sc) in z_row.iter_mut().zip(&scs).zip(&ssc) {
            *zv = Complex::new(sab[h] + cs, sba[h] + sc);
        }
        let x_row = x.row(h);
        for (i, &xv) in x_row.iter().enumerate() {
            kernels::cpm3_acc_crow(z_row, xv, y.row(i));
        }
        for zv in z_row.iter_mut() {
            zv.re >>= 1;
            zv.im >>= 1;
        }
    }

    // hoisted ledger ≡ per-element counting (asserted by tests)
    let (mu, nu, pu) = (m as u64, n as u64, p as u64);
    let ops = OpCounts {
        mults: 0,
        squares: 3 * mu * nu + 3 * nu * pu + 3 * mu * nu * pu,
        adds: 5 * mu * nu + 6 * nu * pu + 2 * mu * pu + 8 * mu * nu * pu,
        shifts: 2 * mu * pu,
    };
    (z, ops)
}

/// Build a complex matrix from planar parts.
pub fn from_planes(re: &Matrix<i64>, im: &Matrix<i64>) -> CMatrix {
    assert_eq!((re.rows, re.cols), (im.rows, im.cols));
    CMatrix::from_fn(re.rows, re.cols, |i, j| Complex::new(re.get(i, j), im.get(i, j)))
}

/// Split a complex matrix into its (re, im) planes — the storage the
/// engine's plane-split CPM3 lowering
/// ([`engine::complex`](super::engine::complex)) operates on.
pub fn to_planes(x: &CMatrix) -> (Matrix<i64>, Matrix<i64>) {
    (x.map(|v| v.re), x.map(|v| v.im))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    fn random_c(rng: &mut Rng, r: usize, c: usize, lim: i64) -> CMatrix {
        CMatrix::from_fn(r, c, |_, _| {
            Complex::new(rng.i64_in(-lim, lim), rng.i64_in(-lim, lim))
        })
    }

    #[test]
    fn all_four_agree() {
        let mut rng = Rng::new(10);
        for _ in 0..30 {
            let (m, n, p) = (
                rng.usize_in(1, 8),
                rng.usize_in(1, 8),
                rng.usize_in(1, 8),
            );
            let x = random_c(&mut rng, m, n, 500);
            let y = random_c(&mut rng, n, p, 500);
            let (d, _) = cmatmul_direct(&x, &y);
            let (k3, _) = cmatmul_3mult(&x, &y);
            let (c4, _) = cmatmul_cpm(&x, &y);
            let (c3, _) = cmatmul_cpm3(&x, &y);
            assert_eq!(d, k3);
            assert_eq!(d, c4);
            assert_eq!(d, c3);
        }
    }

    #[test]
    fn ledgers_match_paper() {
        let mut rng = Rng::new(11);
        for (m, n, p) in [(1usize, 1usize, 1usize), (4, 6, 3), (8, 8, 8)] {
            let x = random_c(&mut rng, m, n, 100);
            let y = random_c(&mut rng, n, p, 100);
            let (_, d) = cmatmul_direct(&x, &y);
            let (_, c4) = cmatmul_cpm(&x, &y);
            let (_, c3) = cmatmul_cpm3(&x, &y);
            let (mu, nu, pu) = (m as u64, n as u64, p as u64);
            assert_eq!(d.mults, 4 * mu * nu * pu);
            // §6: 4·MNP + 2·MN + 2·NP squares
            assert_eq!(c4.squares, 4 * mu * nu * pu + 2 * mu * nu + 2 * nu * pu);
            // §9: 3·MNP + 3·MN + 3·NP squares
            assert_eq!(c3.squares, 3 * mu * nu * pu + 3 * mu * nu + 3 * nu * pu);
            assert_eq!(c4.mults, 0);
            assert_eq!(c3.mults, 0);
        }
    }

    /// Re-derive every complex-matmul ledger the way the seed tree did —
    /// per-element closure counting — and assert the hoisted formulas are
    /// identical, field by field.
    #[test]
    fn hoisted_ledgers_equal_per_element() {
        fn refs(m: usize, n: usize, p: usize) -> [OpCounts; 4] {
            let (mut direct, mut k3, mut c4, mut c3) =
                (OpCounts::ZERO, OpCounts::ZERO, OpCounts::ZERO, OpCounts::ZERO);
            for _ in 0..m * n {
                c4.squares += 2;
                c4.add_n(2);
                c3.squares += 3;
                c3.add_n(5);
            }
            for _ in 0..n * p {
                c4.squares += 2;
                c4.add_n(2);
                c3.squares += 3;
                c3.add_n(6);
            }
            for _out in 0..m * p {
                c4.add();
                c3.add_n(2);
                for _i in 0..n {
                    direct.mults += 4;
                    direct.add_n(4);
                    k3.mults += 3;
                    k3.add_n(7);
                    c4.squares += 4;
                    c4.add_n(8);
                    c3.squares += 3;
                    c3.add_n(8);
                }
                c4.shifts += 2;
                c3.shifts += 2;
            }
            [direct, k3, c4, c3]
        }

        let mut rng = Rng::new(15);
        for (m, n, p) in [(1usize, 1usize, 1usize), (2, 5, 3), (8, 8, 8)] {
            let x = random_c(&mut rng, m, n, 40);
            let y = random_c(&mut rng, n, p, 40);
            let [dref, kref, c4ref, c3ref] = refs(m, n, p);
            assert_eq!(cmatmul_direct(&x, &y).1, dref, "direct {m}x{n}x{p}");
            assert_eq!(cmatmul_3mult(&x, &y).1, kref, "3mult {m}x{n}x{p}");
            assert_eq!(cmatmul_cpm(&x, &y).1, c4ref, "cpm {m}x{n}x{p}");
            assert_eq!(cmatmul_cpm3(&x, &y).1, c3ref, "cpm3 {m}x{n}x{p}");
        }
    }

    #[test]
    fn eq20_eq36_ratios_measured() {
        let mut rng = Rng::new(12);
        for (m, n, p) in [(4usize, 8usize, 4usize), (16, 8, 16)] {
            let x = random_c(&mut rng, m, n, 50);
            let y = random_c(&mut rng, n, p, 50);
            let (_, d) = cmatmul_direct(&x, &y);
            let (_, c4) = cmatmul_cpm(&x, &y);
            let (_, c3) = cmatmul_cpm3(&x, &y);
            let cmults = (d.mults / 4).max(1); // complex mult count
            let r4 = c4.squares as f64 / cmults as f64;
            let r3 = c3.squares as f64 / cmults as f64;
            let (mu, pu) = (m as u64, p as u64);
            assert!((r4 - super::super::counts::eq20_ratio(mu, pu)).abs() < 1e-12);
            assert!((r3 - super::super::counts::eq36_ratio(mu, pu)).abs() < 1e-12);
        }
    }

    #[test]
    fn unit_modulus_simplification() {
        // §6: if Y's entries are unit complex numbers (±1, ±j), Sy_k = −N.
        let mut rng = Rng::new(13);
        let n = 8;
        let units = [
            Complex::new(1, 0),
            Complex::new(-1, 0),
            Complex::new(0, 1),
            Complex::new(0, -1),
        ];
        let y = CMatrix::from_fn(n, 5, |_, _| *rng.choose(&units));
        let sy: Vec<i64> = (0..y.cols)
            .map(|k| -(0..y.rows).map(|i| {
                let v = y.get(i, k);
                v.re * v.re + v.im * v.im
            }).sum::<i64>())
            .collect();
        assert!(sy.iter().all(|&v| v == -(n as i64)));
    }

    #[test]
    fn from_planes_round_trip() {
        let mut rng = Rng::new(14);
        let re = Matrix::random(&mut rng, 3, 4, -9, 9);
        let im = Matrix::random(&mut rng, 3, 4, -9, 9);
        let c = from_planes(&re, &im);
        assert_eq!(c.get(2, 3), Complex::new(re.get(2, 3), im.get(2, 3)));
        let (re2, im2) = to_planes(&c);
        assert_eq!(re2, re);
        assert_eq!(im2, im);
    }
}
