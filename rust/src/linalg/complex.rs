//! Complex matrix multiplication: direct (eq. 15/16), 3-mult (eq. 31),
//! CPM 4-square (eq. 17–19) and CPM3 3-square (eq. 32–35) — all with exact
//! operation ledgers for the eq. (20)/(36) ratio benches.

use crate::arith::complex::{cmul_3mult, cmul_direct, Complex};

use super::counts::OpCounts;
use super::matrix::Matrix;

pub type CMatrix = Matrix<Complex<i64>>;

/// Direct complex matmul (eq. 15/16): M·N·P complex mults = 4·M·N·P real
/// mults. The ledger counts *real* operations.
pub fn cmatmul_direct(x: &CMatrix, y: &CMatrix) -> (CMatrix, OpCounts) {
    assert_eq!(x.cols, y.rows);
    let mut ops = OpCounts::ZERO;
    let mut z = CMatrix::zeros(x.rows, y.cols);
    for h in 0..x.rows {
        for k in 0..y.cols {
            let mut acc = Complex::ZERO;
            for i in 0..x.cols {
                acc += cmul_direct(x.get(h, i), y.get(i, k));
                ops.mults += 4;
                ops.add_n(2 + 2); // product combine + accumulate
            }
            z.set(h, k, acc);
        }
    }
    (z, ops)
}

/// 3-real-mult complex matmul baseline (eq. 31, Karatsuba-style).
pub fn cmatmul_3mult(x: &CMatrix, y: &CMatrix) -> (CMatrix, OpCounts) {
    assert_eq!(x.cols, y.rows);
    let mut ops = OpCounts::ZERO;
    let mut z = CMatrix::zeros(x.rows, y.cols);
    for h in 0..x.rows {
        for k in 0..y.cols {
            let mut acc = Complex::ZERO;
            for i in 0..x.cols {
                acc += cmul_3mult(x.get(h, i), y.get(i, k));
                ops.mults += 3;
                ops.add_n(3 + 2 + 2);
            }
            z.set(h, k, acc);
        }
    }
    (z, ops)
}

/// CPM complex matmul (eq. 17–19): 4 squares per complex product plus the
/// reusable `Sx_h`/`Sy_k` corrections (2·M·N + 2·N·P squares).
pub fn cmatmul_cpm(x: &CMatrix, y: &CMatrix) -> (CMatrix, OpCounts) {
    assert_eq!(x.cols, y.rows);
    let mut ops = OpCounts::ZERO;

    // Sx_h = −Σ_i (a² + b²)  — 2 squares per element of X
    let sx: Vec<i64> = (0..x.rows)
        .map(|h| {
            -x.row(h)
                .iter()
                .map(|v| {
                    ops.squares += 2;
                    ops.add_n(2);
                    v.re * v.re + v.im * v.im
                })
                .sum::<i64>()
        })
        .collect();
    // Sy_k = −Σ_i (c² + s²)
    let sy: Vec<i64> = (0..y.cols)
        .map(|k| {
            -(0..y.rows)
                .map(|i| {
                    ops.squares += 2;
                    ops.add_n(2);
                    let v = y.get(i, k);
                    v.re * v.re + v.im * v.im
                })
                .sum::<i64>()
        })
        .collect();

    let mut z = CMatrix::zeros(x.rows, y.cols);
    for h in 0..x.rows {
        for k in 0..y.cols {
            let corr = sx[h] + sy[k];
            ops.add();
            let (mut re, mut im) = (corr, corr);
            for i in 0..x.cols {
                let xv = x.get(h, i);
                let yv = y.get(i, k);
                let t1 = xv.re + yv.re; // (a+c)
                let t2 = xv.im - yv.im; // (b−s)
                let t3 = xv.im + yv.re; // (b+c)
                let t4 = xv.re + yv.im; // (a+s)
                re += t1 * t1 + t2 * t2;
                im += t3 * t3 + t4 * t4;
                ops.squares += 4;
                ops.add_n(4 + 4);
            }
            ops.shifts += 2;
            z.set(h, k, Complex::new(re >> 1, im >> 1));
        }
    }
    (z, ops)
}

/// CPM3 complex matmul (eq. 32–35): 3 squares per complex product — the
/// `(c+a+b)²` term is computed once and feeds both accumulators — plus the
/// reusable `Sab/Sba/Scs/Ssc` corrections (3·M·N + 3·N·P squares).
pub fn cmatmul_cpm3(x: &CMatrix, y: &CMatrix) -> (CMatrix, OpCounts) {
    assert_eq!(x.cols, y.rows);
    let mut ops = OpCounts::ZERO;

    // eq. (33)/(35) row corrections: (a+b)², a², b² → 3 squares per element
    let mut sab = vec![0i64; x.rows];
    let mut sba = vec![0i64; x.rows];
    for h in 0..x.rows {
        for v in x.row(h) {
            let ab = v.re + v.im;
            let ab2 = ab * ab;
            sab[h] += -ab2 + v.im * v.im;
            sba[h] += -ab2 - v.re * v.re;
            ops.squares += 3;
            ops.add_n(5);
        }
    }
    // eq. (33)/(35) column corrections: c², (c+s)², (s−c)² → 3 squares
    let mut scs = vec![0i64; y.cols];
    let mut ssc = vec![0i64; y.cols];
    for k in 0..y.cols {
        for i in 0..y.rows {
            let v = y.get(i, k);
            let c2 = v.re * v.re;
            let cs = v.re + v.im;
            let sc = v.im - v.re;
            scs[k] += -c2 + cs * cs;
            ssc[k] += -c2 - sc * sc;
            ops.squares += 3;
            ops.add_n(6);
        }
    }

    let mut z = CMatrix::zeros(x.rows, y.cols);
    for h in 0..x.rows {
        for k in 0..y.cols {
            let mut re = sab[h] + scs[k];
            let mut im = sba[h] + ssc[k];
            ops.add_n(2);
            for i in 0..x.cols {
                let xv = x.get(h, i);
                let yv = y.get(i, k);
                let t = yv.re + xv.re + xv.im; // (c+a+b) — shared
                let t = t * t;
                let u = xv.im + yv.re + yv.im; // (b+c+s)
                let v = xv.re + yv.im - yv.re; // (a+s−c)
                re += t - u * u;
                im += t + v * v;
                ops.squares += 3;
                ops.add_n(6 + 2);
            }
            ops.shifts += 2;
            z.set(h, k, Complex::new(re >> 1, im >> 1));
        }
    }
    (z, ops)
}

/// Build a complex matrix from planar parts.
pub fn from_planes(re: &Matrix<i64>, im: &Matrix<i64>) -> CMatrix {
    assert_eq!((re.rows, re.cols), (im.rows, im.cols));
    CMatrix::from_fn(re.rows, re.cols, |i, j| Complex::new(re.get(i, j), im.get(i, j)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    fn random_c(rng: &mut Rng, r: usize, c: usize, lim: i64) -> CMatrix {
        CMatrix::from_fn(r, c, |_, _| {
            Complex::new(rng.i64_in(-lim, lim), rng.i64_in(-lim, lim))
        })
    }

    #[test]
    fn all_four_agree() {
        let mut rng = Rng::new(10);
        for _ in 0..30 {
            let (m, n, p) = (
                rng.usize_in(1, 8),
                rng.usize_in(1, 8),
                rng.usize_in(1, 8),
            );
            let x = random_c(&mut rng, m, n, 500);
            let y = random_c(&mut rng, n, p, 500);
            let (d, _) = cmatmul_direct(&x, &y);
            let (k3, _) = cmatmul_3mult(&x, &y);
            let (c4, _) = cmatmul_cpm(&x, &y);
            let (c3, _) = cmatmul_cpm3(&x, &y);
            assert_eq!(d, k3);
            assert_eq!(d, c4);
            assert_eq!(d, c3);
        }
    }

    #[test]
    fn ledgers_match_paper() {
        let mut rng = Rng::new(11);
        for (m, n, p) in [(1usize, 1usize, 1usize), (4, 6, 3), (8, 8, 8)] {
            let x = random_c(&mut rng, m, n, 100);
            let y = random_c(&mut rng, n, p, 100);
            let (_, d) = cmatmul_direct(&x, &y);
            let (_, c4) = cmatmul_cpm(&x, &y);
            let (_, c3) = cmatmul_cpm3(&x, &y);
            let (mu, nu, pu) = (m as u64, n as u64, p as u64);
            assert_eq!(d.mults, 4 * mu * nu * pu);
            // §6: 4·MNP + 2·MN + 2·NP squares
            assert_eq!(c4.squares, 4 * mu * nu * pu + 2 * mu * nu + 2 * nu * pu);
            // §9: 3·MNP + 3·MN + 3·NP squares
            assert_eq!(c3.squares, 3 * mu * nu * pu + 3 * mu * nu + 3 * nu * pu);
            assert_eq!(c4.mults, 0);
            assert_eq!(c3.mults, 0);
        }
    }

    #[test]
    fn eq20_eq36_ratios_measured() {
        let mut rng = Rng::new(12);
        for (m, n, p) in [(4usize, 8usize, 4usize), (16, 8, 16)] {
            let x = random_c(&mut rng, m, n, 50);
            let y = random_c(&mut rng, n, p, 50);
            let (_, d) = cmatmul_direct(&x, &y);
            let (_, c4) = cmatmul_cpm(&x, &y);
            let (_, c3) = cmatmul_cpm3(&x, &y);
            let cmults = (d.mults / 4).max(1); // complex mult count
            let r4 = c4.squares as f64 / cmults as f64;
            let r3 = c3.squares as f64 / cmults as f64;
            let (mu, pu) = (m as u64, p as u64);
            assert!((r4 - super::super::counts::eq20_ratio(mu, pu)).abs() < 1e-12);
            assert!((r3 - super::super::counts::eq36_ratio(mu, pu)).abs() < 1e-12);
        }
    }

    #[test]
    fn unit_modulus_simplification() {
        // §6: if Y's entries are unit complex numbers (±1, ±j), Sy_k = −N.
        let mut rng = Rng::new(13);
        let n = 8;
        let units = [
            Complex::new(1, 0),
            Complex::new(-1, 0),
            Complex::new(0, 1),
            Complex::new(0, -1),
        ];
        let y = CMatrix::from_fn(n, 5, |_, _| *rng.choose(&units));
        let sy: Vec<i64> = (0..y.cols)
            .map(|k| -(0..y.rows).map(|i| {
                let v = y.get(i, k);
                v.re * v.re + v.im * v.im
            }).sum::<i64>())
            .collect();
        assert!(sy.iter().all(|&v| v == -(n as i64)));
    }

    #[test]
    fn from_planes_round_trip() {
        let mut rng = Rng::new(14);
        let re = Matrix::random(&mut rng, 3, 4, -9, 9);
        let im = Matrix::random(&mut rng, 3, 4, -9, 9);
        let c = from_planes(&re, &im);
        assert_eq!(c.get(2, 3), Complex::new(re.get(2, 3), im.get(2, 3)));
    }
}
