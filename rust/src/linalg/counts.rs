//! The operation ledger behind the paper's ratio claims.
//!
//! Eq. (6), (20) and (36) compare *numbers of squaring operations* against
//! *numbers of multiplications*. [`OpCounts`] is an exact ledger every
//! reference implementation fills in as it runs, so the benches measure the
//! ratios rather than re-deriving them.

use std::fmt;
use std::ops::{Add, AddAssign};

/// Exact operation counts for one computation.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct OpCounts {
    /// general multiplications a·b between distinct data operands
    pub mults: u64,
    /// squaring operations x²
    pub squares: u64,
    /// additions/subtractions
    pub adds: u64,
    /// shifts (the final ÷2 recovery and any scaling)
    pub shifts: u64,
}

impl OpCounts {
    pub const ZERO: Self = Self { mults: 0, squares: 0, adds: 0, shifts: 0 };

    pub fn mult(&mut self) {
        self.mults += 1;
    }

    pub fn square(&mut self) {
        self.squares += 1;
    }

    pub fn add(&mut self) {
        self.adds += 1;
    }

    pub fn shift(&mut self) {
        self.shifts += 1;
    }

    pub fn add_n(&mut self, n: u64) {
        self.adds += n;
    }

    /// squares-per-multiplication ratio vs a given direct-form ledger —
    /// the quantity eq. (6)/(20)/(36) bound.
    pub fn square_ratio_vs(&self, direct: &OpCounts) -> f64 {
        assert_eq!(self.mults, 0, "square-based path performed a general mult");
        self.squares as f64 / direct.mults.max(1) as f64
    }

    /// Gate-area-weighted cost in NAND2-equivalents given per-op costs.
    /// Used by the E4/E6 roll-ups where a squarer ≈ half a multiplier.
    pub fn weighted_cost(&self, mult_cost: f64, square_cost: f64, add_cost: f64) -> f64 {
        self.mults as f64 * mult_cost
            + self.squares as f64 * square_cost
            + self.adds as f64 * add_cost
    }
}

impl Add for OpCounts {
    type Output = Self;
    fn add(self, o: Self) -> Self {
        Self {
            mults: self.mults + o.mults,
            squares: self.squares + o.squares,
            adds: self.adds + o.adds,
            shifts: self.shifts + o.shifts,
        }
    }
}

impl AddAssign for OpCounts {
    fn add_assign(&mut self, o: Self) {
        *self = *self + o;
    }
}

impl fmt::Display for OpCounts {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "mults={} squares={} adds={} shifts={}",
            self.mults, self.squares, self.adds, self.shifts
        )
    }
}

/// Analytic ratio of eq. (6): squares per mult for an (M,N)·(N,P) product.
pub fn eq6_ratio(m: u64, p: u64) -> f64 {
    1.0 + 1.0 / p as f64 + 1.0 / m as f64
}

/// Analytic ratio of eq. (20): 4-square CPM complex matmul.
pub fn eq20_ratio(m: u64, p: u64) -> f64 {
    4.0 + 2.0 / p as f64 + 2.0 / m as f64
}

/// Analytic ratio of eq. (36): 3-square CPM3 complex matmul.
pub fn eq36_ratio(m: u64, p: u64) -> f64 {
    3.0 + 3.0 / p as f64 + 3.0 / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_arithmetic() {
        let mut a = OpCounts::ZERO;
        a.mult();
        a.add_n(3);
        let mut b = OpCounts::ZERO;
        b.square();
        b.shift();
        let c = a + b;
        assert_eq!(c, OpCounts { mults: 1, squares: 1, adds: 3, shifts: 1 });
    }

    #[test]
    fn ratios_tend_to_limits() {
        assert!((eq6_ratio(1, 1) - 3.0).abs() < 1e-12);
        assert!((eq6_ratio(1 << 20, 1 << 20) - 1.0) < 1e-5);
        assert!((eq20_ratio(1 << 20, 1 << 20) - 4.0) < 1e-5);
        assert!((eq36_ratio(1 << 20, 1 << 20) - 3.0) < 1e-5);
    }

    #[test]
    #[should_panic(expected = "general mult")]
    fn ratio_rejects_contaminated_ledger() {
        let mut sq = OpCounts::ZERO;
        sq.mult();
        let direct = OpCounts { mults: 10, ..OpCounts::ZERO };
        let _ = sq.square_ratio_vs(&direct);
    }

    #[test]
    fn weighted_cost_matches_hand_calc() {
        let c = OpCounts { mults: 2, squares: 4, adds: 10, shifts: 0 };
        assert_eq!(c.weighted_cost(100.0, 50.0, 10.0), 200.0 + 200.0 + 100.0);
    }
}
