//! Quantized neural-network inference in the exact integer domain — the
//! paper's AI-inference motivation (§3) where the square trick is *exact*:
//! int8 weights/activations, i64 accumulators, square-based dense layers
//! with the weight-side corrections `Sb_j` pre-computed at load time
//! ("one of the two matrices is to be considered constant", §3).
//!
//! This is the path a silicon deployment of the paper would run: the E6
//! float artifacts prove the stack composes, this module proves the
//! arithmetic is bit-exact end to end in the datapath the PMAC/tensor-core
//! hardware (Fig. 1b/5b) actually implements.

use super::counts::OpCounts;
use super::matmul::col_corrections;
use super::matrix::Matrix;
use crate::testkit::Rng;

/// One quantized dense layer: `y = relu((x·W + b) >> shift)`.
#[derive(Debug, Clone)]
pub struct QLayer {
    /// int8-ranged weights, (in, out)
    pub w: Matrix<i64>,
    /// bias in accumulator scale
    pub bias: Vec<i64>,
    /// right-shift requantisation (power-of-two scale)
    pub shift: u32,
    /// last layer keeps logits linear (no relu, no shift)
    pub linear: bool,
    /// pre-computed `Sb_j = −Σ_k w_kj²` (eq. 5) — the load-time constant
    sb: Vec<i64>,
}

impl QLayer {
    pub fn new(w: Matrix<i64>, bias: Vec<i64>, shift: u32, linear: bool) -> Self {
        assert_eq!(bias.len(), w.cols);
        let mut pre = OpCounts::ZERO;
        let sb = col_corrections(&w, &mut pre);
        Self { w, bias, shift, linear, sb }
    }
}

/// A quantized MLP.
#[derive(Debug, Clone)]
pub struct QMlp {
    pub layers: Vec<QLayer>,
}

/// Which dense-layer arithmetic to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QArith {
    /// ordinary MACs (Fig. 1a / 5a)
    Direct,
    /// partial multiplications seeded with Sa+Sb (Fig. 1b / 5b)
    Square,
}

impl QMlp {
    /// Deterministic random int8 model for the given layer sizes.
    pub fn random(dims: &[usize], seed: u64) -> Self {
        assert!(dims.len() >= 2);
        let mut rng = Rng::new(seed);
        let layers = dims
            .windows(2)
            .enumerate()
            .map(|(li, d)| {
                let w = Matrix::random(&mut rng, d[0], d[1], -127, 127);
                let bias = rng.vec_i64(d[1], -(1 << 10), 1 << 10);
                let last = li + 2 == dims.len();
                // shift keeps activations in int8-ish range given ~d[0]
                // products of |a·b| ≤ 127·127
                let shift = (14 - u64::leading_zeros(d[0] as u64).saturating_sub(50) as u32)
                    .min(14)
                    .max(7);
                QLayer::new(w, bias, shift, last)
            })
            .collect();
        Self { layers }
    }

    /// Run a batch (rows = samples of int8-ranged features). Returns the
    /// logits and the op ledger of the chosen arithmetic.
    pub fn forward(&self, x: &Matrix<i64>, arith: QArith) -> (Matrix<i64>, OpCounts) {
        let mut ops = OpCounts::ZERO;
        let mut h = x.clone();
        for layer in &self.layers {
            assert_eq!(h.cols, layer.w.rows, "layer arity");
            let z = match arith {
                QArith::Direct => {
                    let (z, o) = super::matmul::matmul_direct(&h, &layer.w);
                    ops += o;
                    z
                }
                QArith::Square => {
                    // Sb_j pre-computed at load time; only Sa_i is per-batch
                    let (z, o) =
                        super::matmul::matmul_square_const_b(&h, &layer.w, &layer.sb);
                    ops += o;
                    z
                }
            };
            h = Matrix::from_fn(z.rows, z.cols, |i, j| {
                let v = z.get(i, j) + layer.bias[j];
                if layer.linear {
                    v
                } else {
                    (v >> layer.shift).max(0) // requantise + relu
                }
            });
            ops.adds += (z.rows * z.cols) as u64;
            if !layer.linear {
                ops.shifts += (z.rows * z.cols) as u64;
            }
        }
        (h, ops)
    }

    /// Argmax class per row of a logits matrix.
    pub fn classify(logits: &Matrix<i64>) -> Vec<usize> {
        (0..logits.rows)
            .map(|i| {
                (0..logits.cols)
                    .max_by_key(|&j| logits.get(i, j))
                    .unwrap()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(rng: &mut Rng, rows: usize, cols: usize) -> Matrix<i64> {
        Matrix::random(rng, rows, cols, 0, 127) // uint8-ish activations
    }

    #[test]
    fn square_and_direct_are_bit_identical() {
        let mlp = QMlp::random(&[32, 24, 10], 1);
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            let x = batch(&mut rng, 8, 32);
            let (zd, _) = mlp.forward(&x, QArith::Direct);
            let (zs, _) = mlp.forward(&x, QArith::Square);
            assert_eq!(zd, zs, "quantized inference must be exact");
        }
    }

    #[test]
    fn ledger_shows_amortised_ratio() {
        // weights constant ⇒ squares/mults = 1 + 1/P per layer-ish;
        // for the whole net it must stay well under the naive 1+1/P+1/M
        let mlp = QMlp::random(&[64, 48, 10], 3);
        let mut rng = Rng::new(4);
        let x = batch(&mut rng, 16, 64);
        let (_, od) = mlp.forward(&x, QArith::Direct);
        let (_, os) = mlp.forward(&x, QArith::Square);
        assert_eq!(os.mults, 0);
        let ratio = os.squares as f64 / od.mults as f64;
        // layers: (16,64,48): 1+1/48+… amortised Sb dropped; bound loosely
        assert!(ratio < 1.10, "ratio={ratio}");
        assert!(ratio >= 1.0);
    }

    #[test]
    fn classification_is_deterministic_and_nontrivial() {
        let mlp = QMlp::random(&[16, 12, 4], 5);
        let mut rng = Rng::new(6);
        let x = batch(&mut rng, 32, 16);
        let (z, _) = mlp.forward(&x, QArith::Square);
        let classes = QMlp::classify(&z);
        assert_eq!(classes.len(), 32);
        // not all the same class (weights are random but non-degenerate)
        let first = classes[0];
        assert!(classes.iter().any(|&c| c != first));
        // deterministic across calls
        let (z2, _) = mlp.forward(&x, QArith::Square);
        assert_eq!(QMlp::classify(&z2), classes);
    }

    #[test]
    fn accumulators_stay_in_budget() {
        use crate::arith::fixed::BitBudget;
        // int8 operands, 64-term contraction: budget must fit i64 and the
        // actual values must fit the budget
        let bb = BitBudget::new(8, 64);
        assert!(bb.fits_i64());
        let mlp = QMlp::random(&[64, 10], 7);
        let mut rng = Rng::new(8);
        let x = batch(&mut rng, 4, 64);
        let (z, _) = mlp.forward(&x, QArith::Square);
        for v in z.data() {
            assert!((v.unsigned_abs() as u128) < (1u128 << bb.accumulator_bits()) * 2);
        }
    }
}
