//! First-party benchmark harness (offline substitute for criterion).
//!
//! [`Bench`] runs a closure with warm-up, adaptive iteration count and
//! robust statistics; [`Table`] renders the paper-style result tables the
//! `cargo bench` targets print. Used by every file in `rust/benches/`.

use std::fmt::Write as _;
use std::time::{Duration, Instant};

/// Result of measuring one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Benchmark runner.
pub struct Bench {
    /// target wall time per case
    pub budget: Duration,
    /// number of timed samples
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { budget: Duration::from_millis(300), samples: 12 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { budget: Duration::from_millis(80), samples: 6 }
    }

    /// Measure `f`, preventing the result from being optimised away via
    /// the returned value sink.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Measurement {
        // warm-up + iteration calibration
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.budget / 10 {
            std::hint::black_box(f());
            calib_iters += 1;
            if calib_iters > 1 << 24 {
                break;
            }
        }
        let per_iter = (self.budget.as_nanos() as f64 / 10.0) / calib_iters as f64;
        let per_sample_ns = self.budget.as_nanos() as f64 / self.samples as f64;
        let iters = ((per_sample_ns / per_iter).ceil() as u64).max(1);

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            times.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let median = times[times.len() / 2];
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / times.len() as f64;
        Measurement {
            iters,
            mean_ns: mean,
            median_ns: median,
            stddev_ns: var.sqrt(),
            min_ns: times[0],
        }
    }
}

/// Plain-text table with aligned columns, in the style the paper's tables
/// would print.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n=== {} ===", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                let _ = write!(s, " {c:>w$} |", w = w);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.headers);
        let _ = writeln!(
            out,
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals — table cell helper.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench { budget: Duration::from_millis(20), samples: 4 };
        let m = b.run(|| (0..100u64).sum::<u64>());
        assert!(m.mean_ns > 0.0);
        assert!(m.iters >= 1);
        assert!(m.min_ns <= m.mean_ns * 1.5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["m", "ratio"]);
        t.row(&["8".into(), "1.250".into()]);
        t.row(&["128".into(), "1.016".into()]);
        let s = t.render();
        assert!(s.contains("=== demo ==="));
        assert!(s.contains("|   8 |"));
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(12.3), "12.3 ns");
        assert!(fmt_ns(12_300.0).contains("µs"));
        assert!(fmt_ns(12_300_000.0).contains("ms"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
