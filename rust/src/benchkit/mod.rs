//! First-party benchmark harness (offline substitute for criterion).
//!
//! [`Bench`] runs a closure with warm-up, adaptive iteration count and
//! robust statistics; [`Table`] renders the paper-style result tables the
//! `cargo bench` targets print; [`JsonReport`] writes the same numbers as
//! a machine-readable `BENCH_<name>.json` artifact so the perf trajectory
//! accumulates PR over PR. Used by every file in `rust/benches/`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::config::Json;

/// A counting global allocator for the zero-allocation gates: forwards
/// to the system allocator and counts every `alloc`/`realloc`/
/// `alloc_zeroed` touch. Each gate binary (the `blocked_conv` bench, the
/// `workspace_alloc` integration test) declares its own
/// `#[global_allocator] static A: CountingAlloc = CountingAlloc::new();`
/// and diffs [`CountingAlloc::allocations`] around the measured region —
/// one definition, so the gates can never drift apart on what counts as
/// an allocation.
pub struct CountingAlloc {
    allocs: AtomicU64,
}

impl CountingAlloc {
    pub const fn new() -> Self {
        Self { allocs: AtomicU64::new(0) }
    }

    /// Allocator touches so far (monotone; diff around a region).
    pub fn allocations(&self) -> u64 {
        // SeqCst: counter reads sit outside any timing loop; total order
        // costs nothing here and keeps the gate immune to reordering
        self.allocs.load(Ordering::SeqCst)
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

// SAFETY: pure pass-through to `System` plus a counter bump — layout
// contracts are forwarded verbatim, so System's guarantees carry over.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: same layout handed to System.alloc; the count is a side
    // effect with no aliasing.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // SeqCst: one uncontended RMW per allocation; see `allocations`
        self.allocs.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    // SAFETY: ptr/layout come from a matching alloc on this allocator,
    // which forwarded to System.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: forwarded verbatim; System enforces the realloc contract.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // SeqCst: one uncontended RMW per allocation; see `allocations`
        self.allocs.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: forwarded verbatim to System.alloc_zeroed.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        // SeqCst: one uncontended RMW per allocation; see `allocations`
        self.allocs.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }
}

/// Result of measuring one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Benchmark runner.
pub struct Bench {
    /// target wall time per case
    pub budget: Duration,
    /// number of timed samples
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { budget: Duration::from_millis(300), samples: 12 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { budget: Duration::from_millis(80), samples: 6 }
    }

    /// Measure `f`, preventing the result from being optimised away via
    /// the returned value sink.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Measurement {
        // warm-up + iteration calibration
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.budget / 10 {
            std::hint::black_box(f());
            calib_iters += 1;
            if calib_iters > 1 << 24 {
                break;
            }
        }
        let per_iter = (self.budget.as_nanos() as f64 / 10.0) / calib_iters as f64;
        let per_sample_ns = self.budget.as_nanos() as f64 / self.samples as f64;
        let iters = ((per_sample_ns / per_iter).ceil() as u64).max(1);

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            times.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let median = times[times.len() / 2];
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / times.len() as f64;
        Measurement {
            iters,
            mean_ns: mean,
            median_ns: median,
            stddev_ns: var.sqrt(),
            min_ns: times[0],
        }
    }
}

/// Plain-text table with aligned columns, in the style the paper's tables
/// would print.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n=== {} ===", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                let _ = write!(s, " {c:>w$} |", w = w);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.headers);
        let _ = writeln!(
            out,
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals — table cell helper.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Machine-readable benchmark artifact writer.
///
/// Accumulates named cases (each a [`Measurement`] plus arbitrary extra
/// numeric fields — shapes, speedups, throughput) and writes
/// `BENCH_<name>.json`, using the first-party [`Json`] printer. The
/// artifact is append-friendly history: one file per bench target per
/// run, committed or diffed as the perf trajectory demands.
pub struct JsonReport {
    name: String,
    cases: Vec<Json>,
}

impl JsonReport {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), cases: Vec::new() }
    }

    /// Record one measured case with extra numeric fields.
    pub fn case(&mut self, case: &str, m: &Measurement, extra: &[(&str, f64)]) {
        let mut obj = Json::object();
        obj.insert("name", Json::Str(case.to_string()));
        obj.insert("mean_ns", Json::Num(m.mean_ns));
        obj.insert("median_ns", Json::Num(m.median_ns));
        obj.insert("stddev_ns", Json::Num(m.stddev_ns));
        obj.insert("min_ns", Json::Num(m.min_ns));
        obj.insert("iters", Json::Num(m.iters as f64));
        for &(k, v) in extra {
            obj.insert(k, Json::Num(v));
        }
        self.cases.push(obj);
    }

    pub fn is_empty(&self) -> bool {
        self.cases.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let mut root = Json::object();
        root.insert("bench", Json::Str(self.name.clone()));
        root.insert("schema", Json::Num(1.0));
        let secs = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs() as f64)
            .unwrap_or(0.0);
        root.insert("created_unix", Json::Num(secs));
        root.insert("cases", Json::Arr(self.cases.clone()));
        root
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the written path.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, format!("{}\n", self.to_json()))?;
        Ok(path)
    }

    /// Write into the current directory — `cargo bench` runs in the
    /// package root, so the artifact lands next to `Cargo.toml`.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        self.write_to(Path::new("."))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench { budget: Duration::from_millis(20), samples: 4 };
        let m = b.run(|| (0..100u64).sum::<u64>());
        assert!(m.mean_ns > 0.0);
        assert!(m.iters >= 1);
        assert!(m.min_ns <= m.mean_ns * 1.5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["m", "ratio"]);
        t.row(&["8".into(), "1.250".into()]);
        t.row(&["128".into(), "1.016".into()]);
        let s = t.render();
        assert!(s.contains("=== demo ==="));
        assert!(s.contains("|   8 |"));
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(12.3), "12.3 ns");
        assert!(fmt_ns(12_300.0).contains("µs"));
        assert!(fmt_ns(12_300_000.0).contains("ms"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn json_report_round_trips() {
        let m = Measurement {
            iters: 3,
            mean_ns: 1500.0,
            median_ns: 1400.0,
            stddev_ns: 100.0,
            min_ns: 1300.0,
        };
        let mut r = JsonReport::new("unit_test");
        assert!(r.is_empty());
        r.case("case_a", &m, &[("speedup", 2.5), ("n", 256.0)]);
        assert!(!r.is_empty());

        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("bench").and_then(Json::as_str), Some("unit_test"));
        let cases = parsed.get("cases").and_then(Json::as_arr).unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").and_then(Json::as_str), Some("case_a"));
        assert_eq!(cases[0].get("mean_ns").and_then(Json::as_f64), Some(1500.0));
        assert_eq!(cases[0].get("speedup").and_then(Json::as_f64), Some(2.5));

        let dir = std::env::temp_dir().join("fairsq_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = r.write_to(&dir).unwrap();
        assert!(path.ends_with("BENCH_unit_test.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(path);
    }
}
