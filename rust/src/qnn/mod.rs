//! The exact int8 quantized-inference subsystem: multi-layer
//! [`QMlp`](crate::linalg::qnn::QMlp) pipelines lowered onto the blocked,
//! multi-threaded square-kernel engine — the paper's §3 deployment story
//! served for real. An n-bit squarer costs roughly half an n×n
//! multiplier, and over int8 weights with i64 accumulators the square
//! trick is *exact*, so this is the datapath where the win is honest:
//! integer ops/s with bit-identical results, not float ops/s with an
//! error budget.
//!
//! [`PreparedQnn`] is the load-time artifact: every layer's weight
//! corrections `Sb_j = −Σ_k w_kj²` (eq. 5) are computed **once** —
//! [`PreparedB`] per layer — and shared across a whole serving pool via
//! one `Arc`, the §3 "constant matrix" amortisation extended across
//! layers and workers. The per-request pipeline
//! ([`PreparedQnn::forward_into`]) is *fused*: each layer's GEMM lands in
//! a workspace checkout, the requantisation (`+bias`, `>> shift`,
//! `max(0)` ReLU) is applied **in place** on that buffer, and the buffer
//! is handed to the next layer as its input matrix — no intermediate
//! activation matrix is ever materialised on the heap, so a warmed
//! single-threaded pipeline performs **zero** allocations per batch (the
//! `qnn_serving` bench pins this under a counting allocator).
//!
//! The tile form ([`PreparedQnn::forward_tile_into`]) slots into the
//! serving pool's §3.3 fork path: the request-wide layer-0 activation
//! corrections are hoisted once per request
//! ([`row_corrections_into`] over the full input), tiles then pay only
//! their own rows — and because hidden activations are tile-local, inner
//! layers hoist per tile. The hoisted ledgers
//! ([`PreparedQnn::forward_ledger`], [`PreparedQnn::hoist_ledger`],
//! [`PreparedQnn::tile_ledger`]) reproduce per-element counting exactly:
//! `hoist(m) + Σ tiles == forward(m) ==` the scalar
//! [`QMlp::forward`](crate::linalg::qnn::QMlp::forward) square-arithmetic
//! ledger (the tests assert all three identities).

use std::sync::Arc;

use crate::linalg::engine::{
    matmul_square_prepared_into, matmul_square_prepared_tile_into,
    row_corrections_into, row_corrections_ledger, square_matmul_const_b_ledger,
    square_matmul_tile_ledger, EngineConfig, EngineWorkspace, PreparedB,
};
use crate::linalg::qnn::QMlp;
use crate::linalg::{Matrix, OpCounts};

/// One quantized dense layer, serving form: the weight matrix behind a
/// [`PreparedB`] correction cache (the load-time `Sb` hoist) plus the
/// requantisation constants the fused pipeline applies in place.
#[derive(Debug)]
pub struct PreparedQLayer {
    pb: PreparedB<i64>,
    bias: Vec<i64>,
    shift: u32,
    linear: bool,
}

impl PreparedQLayer {
    /// Input features this layer consumes.
    pub fn in_features(&self) -> usize {
        self.pb.in_features()
    }

    /// Output features this layer produces.
    pub fn out_features(&self) -> usize {
        self.pb.out_features()
    }
}

/// A whole quantized MLP prepared for serving: per-layer `PreparedB`
/// caches, built once per model (or per pool via [`PreparedQnn::new_shared`]).
#[derive(Debug)]
pub struct PreparedQnn {
    layers: Vec<PreparedQLayer>,
}

impl PreparedQnn {
    /// Prepare every layer of `mlp` (computing and caching each layer's
    /// `N·P` correction squares). The returned ledger is the one-time
    /// preparation cost, paid once per model lifetime.
    pub fn new(mlp: &QMlp) -> (Self, OpCounts) {
        assert!(!mlp.layers.is_empty(), "empty model");
        let mut prep_ops = OpCounts::ZERO;
        let mut layers = Vec::with_capacity(mlp.layers.len());
        let mut expect_in = mlp.layers[0].w.rows;
        for layer in &mlp.layers {
            assert_eq!(layer.w.rows, expect_in, "layer arity chain");
            expect_in = layer.w.cols;
            let (pb, ops) = PreparedB::new(layer.w.clone());
            prep_ops += ops;
            layers.push(PreparedQLayer {
                pb,
                bias: layer.bias.clone(),
                shift: layer.shift,
                linear: layer.linear,
            });
        }
        (Self { layers }, prep_ops)
    }

    /// Prepare and wrap for sharing: a serving pool hands every worker a
    /// clone of the returned `Arc`, so the per-layer correction cost is
    /// paid exactly once no matter how many workers serve the model.
    pub fn new_shared(mlp: &QMlp) -> (Arc<Self>, OpCounts) {
        let (p, ops) = Self::new(mlp);
        (Arc::new(p), ops)
    }

    /// Features a request row must carry (layer 0's input arity).
    pub fn in_features(&self) -> usize {
        self.layers[0].pb.in_features()
    }

    /// Logits per request row (the last layer's output arity).
    pub fn out_features(&self) -> usize {
        self.layers[self.layers.len() - 1].pb.out_features()
    }

    /// The prepared layers, in pipeline order.
    pub fn layers(&self) -> &[PreparedQLayer] {
        &self.layers
    }

    /// Hoisted ledger of one fused forward over an `m`-row batch: per
    /// layer the constant-B square matmul
    /// ([`square_matmul_const_b_ledger`]) plus the fused requantisation
    /// (`m·p` bias adds; `m·p` shifts unless the layer is linear).
    /// Equals the scalar [`QMlp::forward`] square-arithmetic ledger,
    /// which is itself asserted against per-element counting.
    pub fn forward_ledger(&self, m: usize) -> OpCounts {
        let mut ops = OpCounts::ZERO;
        for layer in &self.layers {
            ops += square_matmul_const_b_ledger(
                m,
                layer.pb.in_features(),
                layer.pb.out_features(),
            );
            ops += requant_ledger(m, layer.pb.out_features(), layer.linear);
        }
        ops
    }

    /// The once-per-request tile hoist: layer 0's full-input activation
    /// corrections (`m·n₀` squares), paid exactly once no matter how
    /// many tiles the request forks into.
    pub fn hoist_ledger(&self, m: usize) -> OpCounts {
        row_corrections_ledger(m, self.in_features())
    }

    /// Hoisted ledger of ONE `mi`-row tile of the fused pipeline: layer 0
    /// pays only its tile matmul (its corrections were hoisted — see
    /// [`Self::hoist_ledger`]); every inner layer pays a tile-local
    /// correction hoist (hidden activations exist only inside the tile)
    /// plus its tile matmul; every layer pays its tile's requantisation.
    /// Summed over any disjoint tiling of `[0, M)` and added to
    /// [`Self::hoist_ledger`], this reproduces [`Self::forward_ledger`]
    /// exactly (the tests assert it).
    pub fn tile_ledger(&self, mi: usize) -> OpCounts {
        let mut ops = OpCounts::ZERO;
        for (li, layer) in self.layers.iter().enumerate() {
            let (n, p) = (layer.pb.in_features(), layer.pb.out_features());
            if li > 0 {
                ops += row_corrections_ledger(mi, n);
            }
            ops += square_matmul_tile_ledger(mi, n, p);
            ops += requant_ledger(mi, p, layer.linear);
        }
        ops
    }

    /// The fused forward: logits of the `m`-row batch `x` into `out`
    /// (resized to `m·out_features`), every intermediate drawn from `ws`.
    /// Each layer's GEMM lands in a workspace checkout, is requantised
    /// **in place**, and becomes the next layer's input matrix via
    /// `Matrix::from_vec` — no intermediate activation is materialised on
    /// the heap, so once `ws` and `out` are warm the call performs zero
    /// allocations with `cfg.threads == 1` (the scoped threaded driver
    /// allocates per spawn by construction). Returns exactly
    /// [`Self::forward_ledger`]`(m)`.
    pub fn forward_into(
        &self,
        x: &Matrix<i64>,
        cfg: &EngineConfig,
        ws: &mut EngineWorkspace<i64>,
        out: &mut Vec<i64>,
    ) -> OpCounts {
        assert_eq!(x.cols, self.in_features(), "input arity");
        let m = x.rows;
        let last = self.layers.len() - 1;
        let mut ops = OpCounts::ZERO;
        let mut prev: Option<Matrix<i64>> = None;
        for (li, layer) in self.layers.iter().enumerate() {
            let p = layer.pb.out_features();
            // the last layer lands in the caller's reused buffer, hidden
            // layers in a workspace checkout that the next layer consumes
            let mut z = if li == last {
                std::mem::take(out)
            } else {
                ws.checkout(m * p)
            };
            {
                let h = prev.as_ref().unwrap_or(x);
                ops += matmul_square_prepared_into(h, &layer.pb, cfg, ws, &mut z);
            }
            ops += requantise_rows(&mut z, layer);
            if let Some(h) = prev.take() {
                ws.give_back(h.into_data());
            }
            if li == last {
                *out = z;
            } else {
                prev = Some(Matrix::from_vec(m, p, z));
            }
        }
        ops
    }

    /// The fused forward over one §3.3 tile `[i0, i1)` of a request:
    /// `a_full` is the whole request batch, `sa0` its request-wide
    /// layer-0 row corrections (hoisted once by the caller via
    /// [`row_corrections_into`]), and `out_tile` exactly the tile's
    /// logits partition (`(i1−i0)·out_features`, a disjoint sub-slice of
    /// the request output, so concurrent tiles need no locking). Hidden
    /// activations are tile-local, so inner layers hoist their own
    /// corrections here. Values are byte-identical to the untiled
    /// [`Self::forward_into`] rows; the returned ledger is exactly
    /// [`Self::tile_ledger`]`(i1 − i0)`.
    pub fn forward_tile_into(
        &self,
        a_full: &Matrix<i64>,
        sa0: &[i64],
        i0: usize,
        i1: usize,
        out_tile: &mut [i64],
        cfg: &EngineConfig,
        ws: &mut EngineWorkspace<i64>,
    ) -> OpCounts {
        assert!(i0 <= i1 && i1 <= a_full.rows, "tile row range out of bounds");
        let mi = i1 - i0;
        let last = self.layers.len() - 1;
        let mut ops = OpCounts::ZERO;
        let mut prev: Option<Matrix<i64>> = None;
        for (li, layer) in self.layers.iter().enumerate() {
            let p = layer.pb.out_features();
            // lint-ok(warm-alloc): an empty Vec never allocates — the
            // last layer writes through `out_tile` and ignores `hidden`
            let mut hidden = if li == last { Vec::new() } else { ws.checkout(mi * p) };
            {
                let dst: &mut [i64] =
                    if li == last { &mut *out_tile } else { &mut hidden };
                match prev.as_ref() {
                    // layer 0 spends the request-wide hoist the caller paid
                    None => {
                        ops += matmul_square_prepared_tile_into(
                            a_full, &layer.pb, sa0, i0, i1, dst, cfg,
                        );
                    }
                    // hidden activations live only in this tile: hoist here
                    Some(h) => {
                        let mut sa = ws.checkout(mi);
                        row_corrections_into(h, &mut sa);
                        ops += row_corrections_ledger(mi, h.cols);
                        ops += matmul_square_prepared_tile_into(
                            h, &layer.pb, &sa, 0, mi, dst, cfg,
                        );
                        ws.give_back(sa);
                    }
                }
                ops += requantise_rows(dst, layer);
            }
            if let Some(h) = prev.take() {
                ws.give_back(h.into_data());
            }
            if li != last {
                prev = Some(Matrix::from_vec(mi, p, hidden));
            }
        }
        ops
    }
}

/// The fused requantisation: `v = z + bias_j`, then unless the layer is
/// linear `v = max(v >> shift, 0)` — applied **in place** on the layer's
/// GEMM buffer, one pass, no scratch. Identical arithmetic (and ledger)
/// to the scalar [`QMlp::forward`] requantisation.
fn requantise_rows(z: &mut [i64], layer: &PreparedQLayer) -> OpCounts {
    let p = layer.bias.len();
    debug_assert_eq!(z.len() % p, 0);
    for row in z.chunks_mut(p) {
        for (v, &b) in row.iter_mut().zip(&layer.bias) {
            let t = *v + b;
            *v = if layer.linear { t } else { (t >> layer.shift).max(0) };
        }
    }
    requant_ledger(z.len() / p, p, layer.linear)
}

/// Hoisted ledger of the fused requantisation over `m·p` elements.
fn requant_ledger(m: usize, p: usize, linear: bool) -> OpCounts {
    let mp = (m * p) as u64;
    OpCounts {
        adds: mp,
        shifts: if linear { 0 } else { mp },
        ..OpCounts::ZERO
    }
}

/// Argmax class of one logits row, resolving ties to the **highest**
/// index — exactly [`QMlp::classify`]'s `max_by_key` tie-breaking, so
/// the wire client and the scalar oracle can never disagree on a class.
pub fn argmax_logits(row: &[i64]) -> usize {
    assert!(!row.is_empty(), "empty logits row");
    let mut best = 0;
    for (j, &v) in row.iter().enumerate() {
        if v >= row[best] {
            best = j;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qnn::QArith;
    use crate::testkit::Rng;

    fn batch(rng: &mut Rng, rows: usize, cols: usize) -> Matrix<i64> {
        Matrix::random(rng, rows, cols, 0, 127)
    }

    #[test]
    fn fused_pipeline_is_bit_identical_to_scalar_oracle() {
        let mlp = QMlp::random(&[48, 32, 20, 10], 0x91);
        let (prep, _) = PreparedQnn::new(&mlp);
        assert_eq!(prep.in_features(), 48);
        assert_eq!(prep.out_features(), 10);
        assert_eq!(prep.layers().len(), 3);
        let mut rng = Rng::new(0x92);
        let mut ws = EngineWorkspace::new();
        let mut out = Vec::new();
        for cfg in [EngineConfig::default(), EngineConfig::with_threads(2)] {
            for _ in 0..4 {
                let x = batch(&mut rng, 6, 48);
                let (want, _) = mlp.forward(&x, QArith::Direct);
                let ops = prep.forward_into(&x, &cfg, &mut ws, &mut out);
                assert_eq!(out, want.data(), "fused pipeline drifted");
                assert_eq!(ops, prep.forward_ledger(6), "hoisted ledger drifted");
            }
        }
    }

    #[test]
    fn forward_ledger_equals_scalar_per_element_counting() {
        // the scalar QMlp square path counts per call (its own tests pin
        // it to per-element counting); the fused ledger must match it
        let mlp = QMlp::random(&[32, 24, 10], 0x93);
        let (prep, prep_ops) = PreparedQnn::new(&mlp);
        // load-time cost: each layer's N·P correction squares
        assert_eq!(prep_ops.squares, (32 * 24 + 24 * 10) as u64);
        let mut rng = Rng::new(0x94);
        let x = batch(&mut rng, 8, 32);
        let (_, scalar_ops) = mlp.forward(&x, QArith::Square);
        assert_eq!(prep.forward_ledger(8), scalar_ops);
    }

    #[test]
    fn tile_ledgers_and_values_reassemble_the_full_forward() {
        let mlp = QMlp::random(&[24, 16, 8], 0x95);
        let (prep, _) = PreparedQnn::new(&mlp);
        let mut rng = Rng::new(0x96);
        let m = 7;
        let x = batch(&mut rng, m, 24);
        let cfg = EngineConfig::default();
        let mut ws = EngineWorkspace::new();
        let mut full = Vec::new();
        let full_ops = prep.forward_into(&x, &cfg, &mut ws, &mut full);

        // the request-wide layer-0 hoist, once
        let mut sa0 = vec![0i64; m];
        row_corrections_into(&x, &mut sa0);
        let mut tiled = vec![0i64; m * prep.out_features()];
        let mut summed = prep.hoist_ledger(m);
        for (i0, i1) in [(0usize, 3usize), (3, 4), (4, 7)] {
            let out_tile =
                &mut tiled[i0 * prep.out_features()..i1 * prep.out_features()];
            let ops =
                prep.forward_tile_into(&x, &sa0, i0, i1, out_tile, &cfg, &mut ws);
            assert_eq!(ops, prep.tile_ledger(i1 - i0));
            summed += ops;
        }
        assert_eq!(tiled, full, "tiled pipeline drifted from the untiled one");
        assert_eq!(summed, full_ops, "hoist + tiles must reassemble the ledger");
    }

    #[test]
    fn warmed_pipeline_stops_allocating() {
        let mlp = QMlp::random(&[32, 24, 10], 0x97);
        let (prep, _) = PreparedQnn::new(&mlp);
        let mut rng = Rng::new(0x98);
        let cfg = EngineConfig::default(); // threads == 1: the zero-alloc claim
        let mut ws = EngineWorkspace::new();
        let mut out = Vec::new();
        let x = batch(&mut rng, 4, 32);
        prep.forward_into(&x, &cfg, &mut ws, &mut out);
        let warm = ws.grows();
        assert!(warm > 0, "warm-up must populate the arena");
        for _ in 0..5 {
            let x = batch(&mut rng, 4, 32);
            prep.forward_into(&x, &cfg, &mut ws, &mut out);
        }
        assert_eq!(ws.grows(), warm, "steady-state batches must not allocate");
    }

    #[test]
    fn argmax_matches_classify_tie_breaking() {
        let logits = Matrix::from_vec(3, 4, vec![1, 9, 9, 2, -5, -5, -9, -7, 3, 3, 3, 3]);
        let want = QMlp::classify(&logits);
        for i in 0..3 {
            assert_eq!(argmax_logits(logits.row(i)), want[i], "row {i}");
        }
    }

    #[test]
    fn shared_prep_serves_identically_across_clones() {
        let mlp = QMlp::random(&[16, 12, 6], 0x99);
        let (shared, _) = PreparedQnn::new_shared(&mlp);
        let mut rng = Rng::new(0x9A);
        let x = batch(&mut rng, 3, 16);
        let cfg = EngineConfig::default();
        let mut outs = Vec::new();
        for _worker in 0..3 {
            let prep = shared.clone();
            let mut ws = EngineWorkspace::new();
            let mut out = Vec::new();
            prep.forward_into(&x, &cfg, &mut ws, &mut out);
            outs.push(out);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
    }
}
