//! Evaluable combinational netlist with area/delay/power accounting.
//!
//! Gates reference only earlier nodes, so construction order is a valid
//! topological order: evaluation is a single forward pass and the critical
//! path falls out of a running per-node depth. Costs use the standard
//! NAND2-equivalent area model and unit gate delays (XOR counted double),
//! which is what "gate count" means in the paper's reference [1].

/// Index of a node in the netlist.
pub type NodeId = u32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gate {
    /// primary input `k`
    Input(u16),
    Const(bool),
    Not(NodeId),
    And(NodeId, NodeId),
    Or(NodeId, NodeId),
    Xor(NodeId, NodeId),
}

/// NAND2-equivalent areas (typical standard-cell figures).
const AREA_NOT: f64 = 0.5;
const AREA_AND: f64 = 1.5;
const AREA_OR: f64 = 1.5;
const AREA_XOR: f64 = 2.5;

/// Unit delays.
const DELAY_NOT: f64 = 0.5;
const DELAY_AND: f64 = 1.0;
const DELAY_OR: f64 = 1.0;
const DELAY_XOR: f64 = 2.0;

/// A combinational netlist under construction / analysis.
#[derive(Debug, Clone, Default)]
pub struct Netlist {
    gates: Vec<Gate>,
    depth: Vec<f64>,
    pub outputs: Vec<NodeId>,
    n_inputs: u16,
}

/// Aggregate cost numbers for a finished netlist.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostSummary {
    /// number of logic gates (excluding inputs/constants)
    pub gate_count: u64,
    /// NAND2-equivalent area
    pub area: f64,
    /// critical path in unit gate delays
    pub critical_path: f64,
    /// mean toggles per gate per random input pair — switching power proxy
    pub switching: f64,
    pub and_gates: u64,
    pub xor_gates: u64,
    pub or_gates: u64,
    pub not_gates: u64,
}

impl Netlist {
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&mut self, g: Gate, d: f64) -> NodeId {
        let id = self.gates.len() as NodeId;
        self.gates.push(g);
        self.depth.push(d);
        id
    }

    fn depth_of(&self, n: NodeId) -> f64 {
        self.depth[n as usize]
    }

    /// Add a primary input.
    pub fn input(&mut self) -> NodeId {
        let k = self.n_inputs;
        self.n_inputs += 1;
        self.push(Gate::Input(k), 0.0)
    }

    /// Add `n` primary inputs (LSB first).
    pub fn inputs(&mut self, n: usize) -> Vec<NodeId> {
        (0..n).map(|_| self.input()).collect()
    }

    pub fn constant(&mut self, v: bool) -> NodeId {
        self.push(Gate::Const(v), 0.0)
    }

    pub fn not(&mut self, a: NodeId) -> NodeId {
        let d = self.depth_of(a) + DELAY_NOT;
        self.push(Gate::Not(a), d)
    }

    pub fn and(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let d = self.depth_of(a).max(self.depth_of(b)) + DELAY_AND;
        self.push(Gate::And(a, b), d)
    }

    pub fn or(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let d = self.depth_of(a).max(self.depth_of(b)) + DELAY_OR;
        self.push(Gate::Or(a, b), d)
    }

    pub fn xor(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let d = self.depth_of(a).max(self.depth_of(b)) + DELAY_XOR;
        self.push(Gate::Xor(a, b), d)
    }

    /// Half adder → (sum, carry).
    pub fn half_adder(&mut self, a: NodeId, b: NodeId) -> (NodeId, NodeId) {
        (self.xor(a, b), self.and(a, b))
    }

    /// Full adder → (sum, carry).
    pub fn full_adder(&mut self, a: NodeId, b: NodeId, cin: NodeId) -> (NodeId, NodeId) {
        let s1 = self.xor(a, b);
        let sum = self.xor(s1, cin);
        let c1 = self.and(a, b);
        let c2 = self.and(s1, cin);
        let carry = self.or(c1, c2);
        (sum, carry)
    }

    /// Ripple-carry adder over two equal-width vectors (LSB first);
    /// returns `width+1` sum bits.
    pub fn ripple_add(&mut self, a: &[NodeId], b: &[NodeId]) -> Vec<NodeId> {
        assert_eq!(a.len(), b.len());
        let mut out = Vec::with_capacity(a.len() + 1);
        let mut carry: Option<NodeId> = None;
        for (&x, &y) in a.iter().zip(b) {
            let (s, c) = match carry {
                None => self.half_adder(x, y),
                Some(cin) => self.full_adder(x, y, cin),
            };
            out.push(s);
            carry = Some(c);
        }
        out.push(carry.unwrap());
        out
    }

    /// Carry-save reduction of partial-product columns to ≤2 rows, then a
    /// final ripple add — the Wallace/Dadda-style reducer both the
    /// multiplier and squarer share. `columns[w]` lists the bits of weight
    /// `w` (LSB first). Returns the binary sum (LSB first).
    pub fn reduce_columns(&mut self, mut columns: Vec<Vec<NodeId>>) -> Vec<NodeId> {
        loop {
            let max_h = columns.iter().map(Vec::len).max().unwrap_or(0);
            if max_h <= 2 {
                break;
            }
            let mut next: Vec<Vec<NodeId>> = vec![Vec::new(); columns.len() + 1];
            for (w, col) in columns.iter().enumerate() {
                let mut i = 0;
                while col.len() - i >= 3 {
                    let (s, c) = self.full_adder(col[i], col[i + 1], col[i + 2]);
                    next[w].push(s);
                    next[w + 1].push(c);
                    i += 3;
                }
                if col.len() - i == 2 {
                    let (s, c) = self.half_adder(col[i], col[i + 1]);
                    next[w].push(s);
                    next[w + 1].push(c);
                } else if col.len() - i == 1 {
                    next[w].push(col[i]);
                }
            }
            while next.last().is_some_and(Vec::is_empty) {
                next.pop();
            }
            columns = next;
        }
        // final 2-row add (ripple; a CPA in silicon)
        let width = columns.len();
        let zero = self.constant(false);
        let mut row_a = Vec::with_capacity(width);
        let mut row_b = Vec::with_capacity(width);
        for col in &columns {
            row_a.push(*col.first().unwrap_or(&zero));
            row_b.push(*col.get(1).unwrap_or(&zero));
        }
        self.ripple_add(&row_a, &row_b)
    }

    /// Evaluate the netlist for the given input bits.
    pub fn eval(&self, inputs: &[bool]) -> Vec<bool> {
        assert_eq!(inputs.len(), self.n_inputs as usize, "input arity");
        let mut vals = vec![false; self.gates.len()];
        for (i, g) in self.gates.iter().enumerate() {
            vals[i] = match *g {
                Gate::Input(k) => inputs[k as usize],
                Gate::Const(v) => v,
                Gate::Not(a) => !vals[a as usize],
                Gate::And(a, b) => vals[a as usize] & vals[b as usize],
                Gate::Or(a, b) => vals[a as usize] | vals[b as usize],
                Gate::Xor(a, b) => vals[a as usize] ^ vals[b as usize],
            };
        }
        self.outputs.iter().map(|&o| vals[o as usize]).collect()
    }

    /// Evaluate with integer input/output packing (LSB first).
    pub fn eval_u64(&self, words: &[(u64, u32)]) -> u64 {
        let mut bits = Vec::new();
        for &(w, n) in words {
            for i in 0..n {
                bits.push((w >> i) & 1 == 1);
            }
        }
        let out = self.eval(&bits);
        out.iter()
            .enumerate()
            .fold(0u64, |acc, (i, &b)| acc | ((b as u64) << i))
    }

    pub fn n_inputs(&self) -> usize {
        self.n_inputs as usize
    }

    /// Static cost summary plus a Monte-Carlo switching estimate
    /// (`samples` random consecutive input pairs).
    pub fn cost(&self, samples: usize, seed: u64) -> CostSummary {
        let (mut and_g, mut or_g, mut xor_g, mut not_g) = (0u64, 0u64, 0u64, 0u64);
        let mut area = 0.0;
        for g in &self.gates {
            match g {
                Gate::And(..) => {
                    and_g += 1;
                    area += AREA_AND;
                }
                Gate::Or(..) => {
                    or_g += 1;
                    area += AREA_OR;
                }
                Gate::Xor(..) => {
                    xor_g += 1;
                    area += AREA_XOR;
                }
                Gate::Not(_) => {
                    not_g += 1;
                    area += AREA_NOT;
                }
                Gate::Input(_) | Gate::Const(_) => {}
            }
        }
        let critical_path = self
            .outputs
            .iter()
            .map(|&o| self.depth[o as usize])
            .fold(0.0, f64::max);

        // switching proxy: expected toggles per random input transition
        let mut rng = crate::testkit::Rng::new(seed);
        let mut toggles = 0u64;
        let gate_count = and_g + or_g + xor_g + not_g;
        if samples > 0 && gate_count > 0 {
            let n_in = self.n_inputs as usize;
            let mut prev = self.eval_all(&random_bits(&mut rng, n_in));
            for _ in 0..samples {
                let cur = self.eval_all(&random_bits(&mut rng, n_in));
                toggles += prev
                    .iter()
                    .zip(&cur)
                    .filter(|(a, b)| a != b)
                    .count() as u64;
                prev = cur;
            }
        }
        let switching = if samples > 0 && gate_count > 0 {
            toggles as f64 / samples as f64 / gate_count as f64
        } else {
            0.0
        };

        CostSummary {
            gate_count,
            area,
            critical_path,
            switching,
            and_gates: and_g,
            xor_gates: xor_g,
            or_gates: or_g,
            not_gates: not_g,
        }
    }

    fn eval_all(&self, inputs: &[bool]) -> Vec<bool> {
        let mut vals = vec![false; self.gates.len()];
        for (i, g) in self.gates.iter().enumerate() {
            vals[i] = match *g {
                Gate::Input(k) => inputs[k as usize],
                Gate::Const(v) => v,
                Gate::Not(a) => !vals[a as usize],
                Gate::And(a, b) => vals[a as usize] & vals[b as usize],
                Gate::Or(a, b) => vals[a as usize] | vals[b as usize],
                Gate::Xor(a, b) => vals[a as usize] ^ vals[b as usize],
            };
        }
        vals
    }
}

fn random_bits(rng: &mut crate::testkit::Rng, n: usize) -> Vec<bool> {
    (0..n).map(|_| rng.next_u64() & 1 == 1).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_adder_truth_table() {
        for a in [false, true] {
            for b in [false, true] {
                for c in [false, true] {
                    let mut nl = Netlist::new();
                    let (ia, ib, ic) = (nl.input(), nl.input(), nl.input());
                    let (s, cy) = nl.full_adder(ia, ib, ic);
                    nl.outputs = vec![s, cy];
                    let out = nl.eval(&[a, b, c]);
                    let total = a as u8 + b as u8 + c as u8;
                    assert_eq!(out[0], total & 1 == 1);
                    assert_eq!(out[1], total >= 2);
                }
            }
        }
    }

    #[test]
    fn ripple_add_matches_u64() {
        let mut rng = crate::testkit::Rng::new(50);
        for _ in 0..200 {
            let n = rng.usize_in(1, 16) as u32;
            let a = rng.next_u64() & ((1 << n) - 1);
            let b = rng.next_u64() & ((1 << n) - 1);
            let mut nl = Netlist::new();
            let ia = nl.inputs(n as usize);
            let ib = nl.inputs(n as usize);
            let sum = nl.ripple_add(&ia, &ib);
            nl.outputs = sum;
            assert_eq!(nl.eval_u64(&[(a, n), (b, n)]), a + b);
        }
    }

    #[test]
    fn reduce_columns_matches_sum() {
        // columns encode 7 + 6·2 + 3·4 = 31
        let mut nl = Netlist::new();
        let one = nl.constant(true);
        let cols = vec![vec![one; 7], vec![one; 6], vec![one; 3]];
        let out = nl.reduce_columns(cols);
        nl.outputs = out;
        assert_eq!(nl.eval_u64(&[]), 7 + 12 + 12);
    }

    #[test]
    fn cost_counts_gates() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let x = nl.xor(a, b);
        let y = nl.and(a, b);
        let z = nl.or(x, y);
        nl.outputs = vec![z];
        let c = nl.cost(0, 0);
        assert_eq!(c.gate_count, 3);
        assert_eq!((c.and_gates, c.or_gates, c.xor_gates), (1, 1, 1));
        assert!((c.area - (1.5 + 1.5 + 2.5)).abs() < 1e-12);
        assert!((c.critical_path - 3.0).abs() < 1e-12); // xor(2) + or(1)
    }

    #[test]
    fn switching_nonzero_for_active_logic() {
        let mut nl = Netlist::new();
        let a = nl.inputs(8);
        let b = nl.inputs(8);
        let s = nl.ripple_add(&a, &b);
        nl.outputs = s;
        let c = nl.cost(200, 9);
        assert!(c.switching > 0.05 && c.switching < 1.0, "{}", c.switching);
    }
}
