//! Gate-level hardware cost models (experiments E4, F9, F12).
//!
//! The paper's economic argument rests on one claim (§1, citing Chen et
//! al. [1]): *an n-bit squaring circuit requires about half the gate count
//! of an n×n multiplier*. We reproduce that claim structurally instead of
//! quoting it: [`netlist`] is a small evaluable gate-level netlist builder;
//! [`multiplier`] generates real array/CSA-tree multipliers and
//! [`squarer`] generates folded partial-product squarers; both are
//! **verified bit-exactly** against `u64` arithmetic and then measured for
//! NAND2-equivalent area, unit-gate critical path and a switching-activity
//! power proxy. [`blocks`] composes them into the paper's datapath blocks
//! (MAC vs PMAC of Fig. 1, complex multiplier vs CPM of Fig. 9 and CPM3 of
//! Fig. 12) and [`report`] renders the E4/F9/F12 tables.

pub mod approx;
pub mod blocks;
pub mod multiplier;
pub mod netlist;
pub mod report;
pub mod squarer;

pub use netlist::{CostSummary, Netlist, NodeId};
