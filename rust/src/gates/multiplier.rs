//! Gate-level n×n unsigned multipliers.
//!
//! Two partial-product reductions are provided: a plain ripple **array**
//! multiplier (what "n×n multiplier gate count" classically means) and a
//! **CSA-tree** (Wallace-style) variant sharing the same column reducer the
//! squarer uses, so multiplier-vs-squarer comparisons are apples-to-apples.

use super::netlist::{Netlist, NodeId};

/// Generate the n² AND partial products of `a × b` as weight-indexed
/// columns: `columns[w]` holds every `a_i·b_j` with `i+j = w`.
fn partial_product_columns(nl: &mut Netlist, a: &[NodeId], b: &[NodeId]) -> Vec<Vec<NodeId>> {
    let n = a.len();
    let m = b.len();
    let mut cols: Vec<Vec<NodeId>> = vec![Vec::new(); n + m - 1];
    for i in 0..n {
        for j in 0..m {
            let pp = nl.and(a[i], b[j]);
            cols[i + j].push(pp);
        }
    }
    cols
}

/// n×n unsigned multiplier with CSA-tree reduction. Output is 2n bits.
pub fn csa_multiplier(n: usize) -> Netlist {
    assert!(n >= 1 && n <= 24, "sim budget: n in 1..=24");
    let mut nl = Netlist::new();
    let a = nl.inputs(n);
    let b = nl.inputs(n);
    let cols = partial_product_columns(&mut nl, &a, &b);
    let mut out = nl.reduce_columns(cols);
    out.truncate(2 * n);
    nl.outputs = out;
    nl
}

/// Classic ripple array multiplier: n rows of n AND gates, each row added
/// with a ripple-carry adder. Same function, deeper critical path —
/// included as the conservative "gate count of a multiplier" baseline.
pub fn array_multiplier(n: usize) -> Netlist {
    assert!(n >= 1 && n <= 24);
    let mut nl = Netlist::new();
    let a = nl.inputs(n);
    let b = nl.inputs(n);
    let zero = nl.constant(false);

    // acc holds the running partial sum, LSB first, growing to 2n bits
    let mut acc: Vec<NodeId> = a.iter().map(|&ai| nl.and(ai, b[0])).collect();
    for j in 1..n {
        let row: Vec<NodeId> = a.iter().map(|&ai| nl.and(ai, b[j])).collect();
        // add the j-shifted row into acc[j..]
        let mut hi: Vec<NodeId> = acc[j..].to_vec();
        let width = hi.len().max(row.len());
        hi.resize(width, zero);
        let mut rw = row;
        rw.resize(width, zero);
        let sum = nl.ripple_add(&hi, &rw); // width+1 bits
        acc.truncate(j);
        acc.extend(sum);
    }
    acc.truncate(2 * n);
    nl.outputs = acc;
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    fn check_multiplier(make: fn(usize) -> Netlist, n: usize, cases: usize) {
        let nl = make(n);
        let mut rng = Rng::new(60 + n as u64);
        let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
        for _ in 0..cases {
            let a = rng.next_u64() & mask;
            let b = rng.next_u64() & mask;
            assert_eq!(
                nl.eval_u64(&[(a, n as u32), (b, n as u32)]),
                a * b,
                "n={n} a={a} b={b}"
            );
        }
        // corner cases (n ≤ 24 so the product always fits u64)
        for (a, b) in [(0, 0), (mask, mask), (1, mask), (mask, 1)] {
            assert_eq!(nl.eval_u64(&[(a, n as u32), (b, n as u32)]), a * b,
                       "corner n={n}");
        }
    }

    #[test]
    fn csa_multiplier_exact() {
        for n in [1, 2, 3, 4, 8, 12, 16] {
            check_multiplier(csa_multiplier, n, 100);
        }
    }

    #[test]
    fn array_multiplier_exact() {
        for n in [1, 2, 3, 4, 8, 12, 16] {
            check_multiplier(array_multiplier, n, 100);
        }
    }

    #[test]
    fn csa_is_shallower_than_array() {
        let c = csa_multiplier(16).cost(0, 0);
        let a = array_multiplier(16).cost(0, 0);
        assert!(c.critical_path < a.critical_path,
                "csa={} array={}", c.critical_path, a.critical_path);
    }

    #[test]
    fn area_grows_quadratically() {
        let a8 = csa_multiplier(8).cost(0, 0).area;
        let a16 = csa_multiplier(16).cost(0, 0).area;
        let ratio = a16 / a8;
        assert!(ratio > 3.0 && ratio < 5.0, "ratio={ratio}");
    }
}
