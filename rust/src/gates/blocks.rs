//! Datapath blocks composed from the gate-level cores: the MAC vs PMAC of
//! Fig. 1, the complex multiplier vs CPM of Fig. 9 and the CPM3 of Fig. 12.
//!
//! Blocks are *cost compositions*: combinational cores are generated as
//! real netlists (and therefore carry verified area/delay), while adders
//! and registers around them are added with closed-form costs (a ripple
//! stage per bit: 1 FA ≈ 2 XOR + 2 AND + 1 OR ≈ 9.5 NAND2; a DFF ≈ 6
//! NAND2). This mirrors how an RTL estimator would price the Fig. 1/9/12
//! schematics.

use super::multiplier::csa_multiplier;
use super::netlist::CostSummary;
use super::squarer::folded_squarer;

/// NAND2-equivalent area of one full-adder stage.
pub const FA_AREA: f64 = 2.0 * 2.5 + 2.0 * 1.5 + 1.5; // 2 XOR + 2 AND + 1 OR
/// NAND2-equivalent area of one D flip-flop bit.
pub const DFF_AREA: f64 = 6.0;
/// Unit-delay of one ripple stage.
pub const FA_DELAY: f64 = 3.0;

/// Cost roll-up of a datapath block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockCost {
    pub name: &'static str,
    /// combinational NAND2-equivalent area
    pub comb_area: f64,
    /// register NAND2-equivalent area
    pub reg_area: f64,
    /// critical path, unit gate delays
    pub critical_path: f64,
}

impl BlockCost {
    pub fn total_area(&self) -> f64 {
        self.comb_area + self.reg_area
    }
}

fn adder_area(bits: u32) -> f64 {
    bits as f64 * FA_AREA
}

fn reg_area(bits: u32) -> f64 {
    bits as f64 * DFF_AREA
}

/// Fig. 1a: classic multiply–accumulator for n-bit operands over N terms.
/// multiplier (2n out) + accumulator adder + accumulator register.
pub fn mac_block(n: usize, n_terms: u64) -> BlockCost {
    let mult: CostSummary = csa_multiplier(n).cost(0, 0);
    let growth = 64 - u64::leading_zeros(n_terms.max(1) - 1).min(63);
    let acc_bits = 2 * n as u32 + growth + 1;
    BlockCost {
        name: "MAC (Fig.1a)",
        comb_area: mult.area + adder_area(acc_bits),
        reg_area: reg_area(acc_bits),
        critical_path: mult.critical_path + FA_DELAY * acc_bits as f64 / 4.0,
    }
}

/// Fig. 1b: partial-multiplication accumulator — one (n+1)-bit operand
/// adder, one (n+1)-bit squarer, accumulator adder + register (2 bits
/// wider, see `arith::fixed::BitBudget`).
pub fn pmac_block(n: usize, n_terms: u64) -> BlockCost {
    let sq: CostSummary = folded_squarer(n + 1).cost(0, 0);
    let growth = 64 - u64::leading_zeros(n_terms.max(1) - 1).min(63);
    let acc_bits = 2 * (n as u32 + 1) + growth + 1;
    BlockCost {
        name: "PMAC (Fig.1b)",
        comb_area: adder_area(n as u32 + 1) + sq.area + adder_area(acc_bits),
        reg_area: reg_area(acc_bits),
        critical_path: FA_DELAY + sq.critical_path + FA_DELAY * acc_bits as f64 / 4.0,
    }
}

/// Fig. 9b: complex multiplier from 3 real multipliers (the paper's
/// comparison baseline) + 5 operand adders.
pub fn complex_mult_3m_block(n: usize) -> BlockCost {
    let mult = csa_multiplier(n).cost(0, 0);
    BlockCost {
        name: "CMUL-3M (Fig.9b)",
        comb_area: 3.0 * mult.area + 5.0 * adder_area(2 * n as u32),
        reg_area: 0.0,
        critical_path: FA_DELAY + mult.critical_path + FA_DELAY,
    }
}

/// Fig. 9a: CPM — 4 squarers of width n+1 plus 4 operand adders and 2
/// combine adders.
pub fn cpm_block(n: usize) -> BlockCost {
    let sq = folded_squarer(n + 1).cost(0, 0);
    BlockCost {
        name: "CPM (Fig.9a)",
        comb_area: 4.0 * sq.area
            + 4.0 * adder_area(n as u32 + 1)
            + 2.0 * adder_area(2 * (n as u32 + 1)),
        reg_area: 0.0,
        critical_path: FA_DELAY + sq.critical_path + FA_DELAY,
    }
}

/// Fig. 12a: CPM3 — 3 squarers of width n+2 (three-operand sums), 5
/// operand adders, 2 combine adders.
pub fn cpm3_block(n: usize) -> BlockCost {
    let sq = folded_squarer(n + 2).cost(0, 0);
    BlockCost {
        name: "CPM3 (Fig.12a)",
        comb_area: 3.0 * sq.area
            + 5.0 * adder_area(n as u32 + 2)
            + 2.0 * adder_area(2 * (n as u32 + 2)),
        reg_area: 0.0,
        critical_path: 2.0 * FA_DELAY + sq.critical_path + FA_DELAY,
    }
}

/// Fig. 9-equivalent direct complex multiplier with 4 real multipliers.
pub fn complex_mult_4m_block(n: usize) -> BlockCost {
    let mult = csa_multiplier(n).cost(0, 0);
    BlockCost {
        name: "CMUL-4M (eq.16)",
        comb_area: 4.0 * mult.area + 2.0 * adder_area(2 * n as u32),
        reg_area: 0.0,
        critical_path: mult.critical_path + FA_DELAY,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmac_saves_combinational_area_vs_mac() {
        // the paper's headline: squarer ≈ ½ multiplier ⇒ PMAC < MAC
        for n in [8usize, 12, 16] {
            let mac = mac_block(n, 256);
            let pmac = pmac_block(n, 256);
            assert!(
                pmac.comb_area < mac.comb_area,
                "n={n}: pmac={} mac={}",
                pmac.comb_area,
                mac.comb_area
            );
        }
    }

    #[test]
    fn pmac_register_overhead_is_real() {
        // honest accounting: the PMAC register is wider
        let mac = mac_block(12, 256);
        let pmac = pmac_block(12, 256);
        assert!(pmac.reg_area > mac.reg_area);
    }

    #[test]
    fn cpm_beats_4m_and_cpm3_beats_cpm() {
        for n in [8usize, 12, 16] {
            let m4 = complex_mult_4m_block(n);
            let m3 = complex_mult_3m_block(n);
            let c4 = cpm_block(n);
            let c3 = cpm3_block(n);
            assert!(c4.comb_area < m4.comb_area, "n={n} CPM vs 4M");
            assert!(c3.comb_area < c4.comb_area, "n={n} CPM3 vs CPM");
            assert!(c3.comb_area < m3.comb_area, "n={n} CPM3 vs 3M");
        }
    }

    #[test]
    fn block_totals_add_up() {
        let b = mac_block(8, 16);
        assert!((b.total_area() - (b.comb_area + b.reg_area)).abs() < 1e-12);
    }
}
