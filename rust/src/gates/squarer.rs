//! Gate-level n-bit unsigned squarers with folded partial products.
//!
//! The classical squarer optimisation behind the paper's cost claim:
//! in `x² = Σᵢⱼ xᵢxⱼ·2^(i+j)` the matrix of partial products is symmetric,
//! so
//!
//! * diagonal terms `xᵢxᵢ = xᵢ` — **free** (a wire, no AND gate);
//! * off-diagonal pairs `xᵢxⱼ + xⱼxᵢ = 2·xᵢxⱼ` — **one** AND gate placed
//!   one column to the left (the ×2 is a shift).
//!
//! That folds n² partial products down to n(n−1)/2 ANDs + n wires, which
//! is where the ≈½ area of Chen et al. [1] comes from. A further classic
//! refinement (`xᵢ + 2·xᵢxᵢ₊₁` → `xᵢx̄ᵢ₊₁` in column 2i and `xᵢxᵢ₊₁` in
//! column 2i+1) is implemented as [`folded_squarer_opt`] and benched as an
//! ablation.

use super::netlist::{Netlist, NodeId};

/// Folded-partial-product squarer. Output is 2n bits.
pub fn folded_squarer(n: usize) -> Netlist {
    assert!(n >= 1 && n <= 24);
    let mut nl = Netlist::new();
    let x = nl.inputs(n);
    let mut cols: Vec<Vec<NodeId>> = vec![Vec::new(); 2 * n];

    // diagonal: x_i² = x_i at weight 2i — zero gates
    for i in 0..n {
        cols[2 * i].push(x[i]);
    }
    // folded off-diagonal: one AND at weight i+j+1 for each i<j
    for i in 0..n {
        for j in (i + 1)..n {
            let pp = nl.and(x[i], x[j]);
            cols[i + j + 1].push(pp);
        }
    }
    while cols.last().is_some_and(Vec::is_empty) {
        cols.pop();
    }
    let mut out = nl.reduce_columns(cols);
    out.truncate(2 * n);
    nl.outputs = out;
    nl
}

/// Folded squarer with the classical adjacent-bit merge: column `2i`
/// (i ≥ 1) holds both the diagonal `x_i` and the folded pair
/// `x_{i−1}·x_i` (weight (i−1)+i+1 = 2i). The identity
///
/// ```text
/// x_i + x_{i−1}x_i  =  2·(x_{i−1}x_i) + x̄_{i−1}x_i
/// ```
///
/// replaces those two same-column bits by one bit at 2i (`x̄_{i−1}·x_i`)
/// and one at 2i+1 (`x_{i−1}·x_i`), shaving a row off the reduction tree
/// at the cost of a NOT+AND. Verified exact below; benched as an ablation.
pub fn folded_squarer_opt(n: usize) -> Netlist {
    assert!(n >= 1 && n <= 24);
    let mut nl = Netlist::new();
    let x = nl.inputs(n);
    let mut cols: Vec<Vec<NodeId>> = vec![Vec::new(); 2 * n];

    cols[0].push(x[0]);
    for i in 1..n {
        let np = nl.not(x[i - 1]);
        let lo = nl.and(np, x[i]);      // x̄_{i−1}·x_i @ 2i
        let hi = nl.and(x[i - 1], x[i]); // x_{i−1}·x_i @ 2i+1
        cols[2 * i].push(lo);
        cols[2 * i + 1].push(hi);
    }
    // remaining folded off-diagonal pairs j ≥ i+2 at weight i+j+1
    for i in 0..n {
        for j in (i + 2)..n {
            let pp = nl.and(x[i], x[j]);
            cols[i + j + 1].push(pp);
        }
    }
    while cols.last().is_some_and(Vec::is_empty) {
        cols.pop();
    }
    let mut out = nl.reduce_columns(cols);
    out.truncate(2 * n);
    nl.outputs = out;
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    fn check_squarer(make: fn(usize) -> Netlist, n: usize) {
        let nl = make(n);
        let mask = (1u64 << n) - 1;
        // exhaustive up to 12 bits, sampled above
        if n <= 12 {
            for v in 0..=mask {
                assert_eq!(nl.eval_u64(&[(v, n as u32)]), v * v, "n={n} v={v}");
            }
        } else {
            let mut rng = Rng::new(70 + n as u64);
            for _ in 0..500 {
                let v = rng.next_u64() & mask;
                assert_eq!(nl.eval_u64(&[(v, n as u32)]), v * v, "n={n} v={v}");
            }
            for v in [0, 1, mask, mask - 1] {
                assert_eq!(nl.eval_u64(&[(v, n as u32)]), v * v);
            }
        }
    }

    #[test]
    fn folded_squarer_exact() {
        for n in [1, 2, 3, 4, 8, 10, 12, 16, 20] {
            check_squarer(folded_squarer, n);
        }
    }

    #[test]
    fn folded_squarer_opt_exact() {
        for n in [1, 2, 3, 4, 8, 10, 12, 16, 20] {
            check_squarer(folded_squarer_opt, n);
        }
    }

    #[test]
    fn squarer_area_is_about_half_of_multiplier() {
        // the paper's E4 claim, at representative widths
        use super::super::multiplier::csa_multiplier;
        for n in [8usize, 12, 16] {
            let sq = folded_squarer(n).cost(0, 0).area;
            let mu = csa_multiplier(n).cost(0, 0).area;
            let ratio = sq / mu;
            assert!(ratio > 0.35 && ratio < 0.65, "n={n} ratio={ratio}");
        }
    }

    #[test]
    fn folding_halves_the_and_count() {
        for n in [8usize, 16] {
            let sq = folded_squarer(n).cost(0, 0);
            // n(n-1)/2 PP ANDs + reduction ANDs; PP AND count alone must be
            // under half the multiplier's n²
            assert!(sq.and_gates as usize >= n * (n - 1) / 2);
        }
    }
}
