//! Report generator for the gate-level experiments (E4, F9, F12).

use super::blocks::{
    complex_mult_3m_block, complex_mult_4m_block, cpm3_block, cpm_block, mac_block,
    pmac_block,
};
use super::multiplier::{array_multiplier, csa_multiplier};
use super::squarer::{folded_squarer, folded_squarer_opt};

/// One row of the E4 table: multiplier vs squarer at width n.
#[derive(Debug, Clone, Copy)]
pub struct CoreRow {
    pub n: usize,
    pub mult_gates: u64,
    pub mult_area: f64,
    pub mult_delay: f64,
    pub sq_gates: u64,
    pub sq_area: f64,
    pub sq_delay: f64,
    /// squarer area / multiplier area — the paper's ≈0.5 claim
    pub area_ratio: f64,
    pub mult_switching: f64,
    pub sq_switching: f64,
}

/// Generate the E4 core comparison for the given operand widths.
/// `switching_samples > 0` adds the Monte-Carlo power proxy (slower).
pub fn core_comparison(widths: &[usize], switching_samples: usize) -> Vec<CoreRow> {
    widths
        .iter()
        .map(|&n| {
            let m = csa_multiplier(n).cost(switching_samples, 0xE4);
            let s = folded_squarer(n).cost(switching_samples, 0xE4);
            CoreRow {
                n,
                mult_gates: m.gate_count,
                mult_area: m.area,
                mult_delay: m.critical_path,
                sq_gates: s.gate_count,
                sq_area: s.area,
                sq_delay: s.critical_path,
                area_ratio: s.area / m.area,
                mult_switching: m.switching,
                sq_switching: s.switching,
            }
        })
        .collect()
}

/// Ablation row: reduction/architecture variants at width n.
#[derive(Debug, Clone, Copy)]
pub struct AblationRow {
    pub name: &'static str,
    pub n: usize,
    pub gates: u64,
    pub area: f64,
    pub delay: f64,
}

/// E4 ablation: array vs CSA multiplier, folded vs merged squarer.
pub fn ablation(widths: &[usize]) -> Vec<AblationRow> {
    let mut rows = Vec::new();
    for &n in widths {
        for (name, nl) in [
            ("mult/array", array_multiplier(n)),
            ("mult/csa", csa_multiplier(n)),
            ("square/folded", folded_squarer(n)),
            ("square/folded+merge", folded_squarer_opt(n)),
        ] {
            let c = nl.cost(0, 0);
            rows.push(AblationRow { name, n, gates: c.gate_count, area: c.area, delay: c.critical_path });
        }
    }
    rows
}

/// One row of the F9/F12 block table.
#[derive(Debug, Clone, Copy)]
pub struct BlockRow {
    pub name: &'static str,
    pub n: usize,
    pub comb_area: f64,
    pub reg_area: f64,
    pub total_area: f64,
    pub critical_path: f64,
    /// area relative to the baseline block of its group
    pub rel_area: f64,
}

/// F1 (MAC vs PMAC) and F9/F12 (complex multiplier vs CPM vs CPM3) tables.
pub fn block_comparison(widths: &[usize], n_terms: u64) -> Vec<BlockRow> {
    let mut rows = Vec::new();
    for &n in widths {
        let mac = mac_block(n, n_terms);
        let pmac = pmac_block(n, n_terms);
        let base = mac.total_area();
        for b in [mac, pmac] {
            rows.push(BlockRow {
                name: b.name,
                n,
                comb_area: b.comb_area,
                reg_area: b.reg_area,
                total_area: b.total_area(),
                critical_path: b.critical_path,
                rel_area: b.total_area() / base,
            });
        }
        let m4 = complex_mult_4m_block(n);
        let base_c = m4.total_area();
        for b in [m4, complex_mult_3m_block(n), cpm_block(n), cpm3_block(n)] {
            rows.push(BlockRow {
                name: b.name,
                n,
                comb_area: b.comb_area,
                reg_area: b.reg_area,
                total_area: b.total_area(),
                critical_path: b.critical_path,
                rel_area: b.total_area() / base_c,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_ratio_near_half() {
        let rows = core_comparison(&[8, 12, 16], 0);
        for r in &rows {
            assert!(r.area_ratio > 0.35 && r.area_ratio < 0.65,
                    "n={} ratio={}", r.n, r.area_ratio);
        }
        // ratio should not *grow* with width
        assert!(rows.last().unwrap().area_ratio <= rows[0].area_ratio + 0.05);
    }

    #[test]
    fn ablation_has_all_variants() {
        let rows = ablation(&[8]);
        assert_eq!(rows.len(), 4);
        let csa = rows.iter().find(|r| r.name == "mult/csa").unwrap();
        let arr = rows.iter().find(|r| r.name == "mult/array").unwrap();
        assert!(csa.delay < arr.delay);
    }

    #[test]
    fn block_rows_have_sane_relatives() {
        let rows = block_comparison(&[12], 256);
        let pmac = rows.iter().find(|r| r.name.starts_with("PMAC")).unwrap();
        assert!(pmac.rel_area < 1.0, "PMAC rel={}", pmac.rel_area);
        let cpm3 = rows.iter().find(|r| r.name.starts_with("CPM3")).unwrap();
        assert!(cpm3.rel_area < 1.0);
    }
}
