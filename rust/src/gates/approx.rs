//! Approximate squarers — the paper's abstract notes the technique
//! "transcends the particular implementation of a squaring circuit.
//! Approximate squaring is also a possibility." This module makes that
//! concrete with the two standard approximation families from the Chen et
//! al. reference [1], with measured (not modelled) error statistics:
//!
//! * [`truncated_squarer`] — drop the k least-significant partial-product
//!   columns (plus an optional constant compensation bias);
//! * [`approx_squarer_lsb`] — replace the LSB half of the folded PP matrix
//!   with its probabilistic expectation (constant), keeping only the MSB
//!   reduction exact.
//!
//! Error metrics are computed by exhaustive/sampled evaluation of the
//! actual netlist, so the area-vs-accuracy trade-off table in the
//! `gate_counts` bench is backed by real gate evaluations.

use super::netlist::{Netlist, NodeId};
use crate::testkit::Rng;

/// Folded squarer with the `k` least-significant output columns truncated
/// (their partial products never generated). `compensate` adds the
/// expected value of the dropped mass as a constant.
pub fn truncated_squarer(n: usize, k: usize, compensate: bool) -> Netlist {
    assert!(n >= 1 && n <= 24 && k < 2 * n);
    let mut nl = Netlist::new();
    let x = nl.inputs(n);
    let mut cols: Vec<Vec<NodeId>> = vec![Vec::new(); 2 * n];

    let mut dropped_weight = 0.0f64;
    // diagonal: x_i at weight 2i
    for i in 0..n {
        if 2 * i >= k {
            cols[2 * i].push(x[i]);
        } else {
            dropped_weight += 0.5 * (1u64 << (2 * i)) as f64; // E[x_i]=½
        }
    }
    // folded pairs at weight i+j+1
    for i in 0..n {
        for j in (i + 1)..n {
            let w = i + j + 1;
            if w >= k {
                let pp = nl.and(x[i], x[j]);
                cols[w].push(pp);
            } else {
                dropped_weight += 0.25 * (1u64 << w) as f64; // E[x_i x_j]=¼
            }
        }
    }
    if compensate && dropped_weight > 0.0 {
        // add round(E[dropped]) as a constant
        let bias = dropped_weight.round() as u64;
        for (w, col) in cols.iter_mut().enumerate() {
            if (bias >> w) & 1 == 1 {
                let one = nl.constant(true);
                col.push(one);
            }
        }
    }
    while cols.last().is_some_and(Vec::is_empty) {
        cols.pop();
    }
    let mut out = nl.reduce_columns(cols);
    out.truncate(2 * n);
    nl.outputs = out;
    nl
}

/// Folded squarer that zeroes every partial product whose weight falls in
/// the lower half (weights < n), replacing the whole lower half with the
/// mean compensation constant — the aggressive "half-exact" design point.
pub fn approx_squarer_lsb(n: usize) -> Netlist {
    truncated_squarer(n, n, true)
}

/// Measured error statistics of an approximate squarer against exact x².
#[derive(Debug, Clone, Copy)]
pub struct ApproxError {
    /// mean of |approx − exact| / 2^{2n}
    pub mean_abs_norm: f64,
    /// max of |approx − exact| / 2^{2n}
    pub max_abs_norm: f64,
    /// mean relative error |approx − exact| / max(exact, 1)
    pub mean_rel: f64,
}

/// Evaluate an approximate squarer netlist against exact squaring —
/// exhaustive for n ≤ 12, sampled otherwise.
pub fn measure_error(nl: &Netlist, n: usize, seed: u64) -> ApproxError {
    let mask = (1u64 << n) - 1;
    let norm = (1u64 << (2 * n)) as f64;
    let mut count = 0u64;
    let mut sum_abs = 0.0f64;
    let mut max_abs = 0.0f64;
    let mut sum_rel = 0.0f64;
    let mut eval = |v: u64| {
        let got = nl.eval_u64(&[(v, n as u32)]) as i64;
        let want = (v * v) as i64;
        let err = (got - want).abs() as f64;
        sum_abs += err / norm;
        max_abs = max_abs.max(err / norm);
        sum_rel += err / (want.max(1)) as f64;
        count += 1;
    };
    if n <= 12 {
        for v in 0..=mask {
            eval(v);
        }
    } else {
        let mut rng = Rng::new(seed);
        for _ in 0..4096 {
            eval(rng.next_u64() & mask);
        }
    }
    ApproxError {
        mean_abs_norm: sum_abs / count as f64,
        max_abs_norm: max_abs,
        mean_rel: sum_rel / count as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::squarer::folded_squarer;

    #[test]
    fn zero_truncation_is_exact() {
        let nl = truncated_squarer(8, 0, false);
        for v in 0..256u64 {
            assert_eq!(nl.eval_u64(&[(v, 8)]), v * v);
        }
    }

    #[test]
    fn truncation_saves_area_monotonically() {
        let base = folded_squarer(12).cost(0, 0).area;
        let mut prev = base + 1.0;
        for k in [0usize, 4, 8, 12] {
            let a = truncated_squarer(12, k, false).cost(0, 0).area;
            assert!(a <= prev, "k={k}: {a} > {prev}");
            prev = a;
        }
        assert!(truncated_squarer(12, 12, false).cost(0, 0).area < 0.8 * base);
    }

    #[test]
    fn error_grows_with_truncation_but_stays_bounded() {
        let mut prev = -1.0;
        for k in [0usize, 2, 4, 6, 8] {
            let nl = truncated_squarer(10, k, true);
            let e = measure_error(&nl, 10, 1);
            assert!(e.max_abs_norm >= prev - 1e-12, "k={k}");
            prev = e.max_abs_norm;
            // dropped mass is bounded by sum of dropped column weights
            let bound = (1u64 << k) as f64 / (1u64 << 20) as f64 * 4.0;
            assert!(e.max_abs_norm <= bound + 1e-9, "k={k}: {} > {bound}", e.max_abs_norm);
        }
    }

    #[test]
    fn compensation_reduces_mean_error() {
        let raw = measure_error(&truncated_squarer(10, 8, false), 10, 2);
        let comp = measure_error(&truncated_squarer(10, 8, true), 10, 2);
        assert!(comp.mean_abs_norm <= raw.mean_abs_norm,
                "comp {} vs raw {}", comp.mean_abs_norm, raw.mean_abs_norm);
    }

    #[test]
    fn lsb_half_design_point() {
        let nl = approx_squarer_lsb(12);
        let e = measure_error(&nl, 12, 3);
        // half the columns dropped: relative error small vs full scale
        assert!(e.max_abs_norm < 1e-2, "{e:?}");
        let exact_area = folded_squarer(12).cost(0, 0).area;
        let approx_area = nl.cost(0, 0).area;
        assert!(approx_area < 0.75 * exact_area);
    }
}
