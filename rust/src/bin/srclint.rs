//! `srclint` — the repo's std-only static-analysis gate.
//!
//! Scans `rust/src/**/*.rs` with the rules in [`fairsquare::analysis`],
//! runs the bounded interleaving models in
//! [`fairsquare::sim::interleave`], writes `ANALYSIS_report.json`, and
//! exits nonzero on any finding, inventory mismatch, or interleaving
//! violation. `scripts/verify.sh` runs this as a hard gate.
//!
//! ```text
//! srclint [--root PATH] [--report PATH] [--clippy-ran true|false]
//!         [--fixture-registry] [--no-interleave] [--lanes CSV]
//!         [--update-inventory]
//! ```
//!
//! `--root` may be a directory or a single file (the fixture tests point
//! it at one known-bad snippet at a time). `--fixture-registry` swaps in
//! the narrow fixture policy so the snippets under
//! `rust/tests/srclint_fixtures/` trip exactly their intended rule.
//! `--lanes` records which verification lanes ran (default / miri /
//! tsan) in the report. `--update-inventory` regenerates
//! `analysis/unsafe_inventory.txt` context hashes mechanically,
//! preserving per-site comments keyed by `(file, hash)`, then exits.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use fairsquare::analysis::{self, rules, scanner, Registry};
use fairsquare::sim::interleave;

struct Opts {
    root: PathBuf,
    report: PathBuf,
    clippy_ran: Option<bool>,
    fixture_registry: bool,
    run_interleave: bool,
    lanes: Vec<String>,
    update_inventory: bool,
}

fn parse_args() -> Result<Opts, String> {
    let mut opts = Opts {
        root: PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src")),
        report: PathBuf::from("ANALYSIS_report.json"),
        clippy_ran: None,
        fixture_registry: false,
        run_interleave: true,
        lanes: vec!["default".to_string()],
        update_inventory: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => {
                opts.root = PathBuf::from(args.next().ok_or("--root needs a path")?);
            }
            "--report" => {
                opts.report = PathBuf::from(args.next().ok_or("--report needs a path")?);
            }
            "--clippy-ran" => {
                let v = args.next().ok_or("--clippy-ran needs true|false")?;
                opts.clippy_ran = Some(match v.as_str() {
                    "true" | "1" => true,
                    "false" | "0" => false,
                    other => return Err(format!("--clippy-ran: expected true|false, got {other}")),
                });
            }
            "--fixture-registry" => opts.fixture_registry = true,
            "--no-interleave" => opts.run_interleave = false,
            "--lanes" => {
                let v = args.next().ok_or("--lanes needs a comma-separated list")?;
                opts.lanes = v
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(String::from)
                    .collect();
            }
            "--update-inventory" => opts.update_inventory = true,
            "--help" | "-h" => {
                println!(
                    "srclint [--root PATH] [--report PATH] [--clippy-ran true|false] \
                     [--fixture-registry] [--no-interleave] [--lanes CSV] [--update-inventory]"
                );
                std::process::exit(0);
            }
            other => return Err(format!("unknown argument: {other}")),
        }
    }
    Ok(opts)
}

/// Regenerate `analysis/unsafe_inventory.txt` under `root`: rescan the
/// tree, rehash every non-test unsafe site, keep the header and any
/// comment whose `(file, hash)` pair still matches, and annotate new
/// sites with their source line. The checked-in file is baked into the
/// binary via `include_str!`, so a rebuild is needed before the updated
/// inventory takes effect.
fn update_inventory(root: &Path) -> Result<(), String> {
    let scans = scanner::scan_tree(root).map_err(|e| format!("scan failed: {e:#}"))?;
    let inv_path = root.join("analysis").join("unsafe_inventory.txt");
    let old = std::fs::read_to_string(&inv_path).unwrap_or_default();

    // header = leading comment/blank block; comments keyed by (file, hash)
    let mut header = String::new();
    let mut in_header = true;
    let mut kept: Vec<(String, String, String)> = Vec::new();
    for line in old.lines() {
        let trimmed = line.trim();
        if in_header && (trimmed.is_empty() || trimmed.starts_with('#')) {
            header.push_str(line);
            header.push('\n');
            continue;
        }
        in_header = false;
        let body = line.split('#').next().unwrap_or("").trim();
        let comment = line.find('#').map(|p| line[p..].trim_end().to_string());
        let mut it = body.split_whitespace();
        if let (Some(f), Some(h)) = (it.next(), it.next()) {
            kept.push((f.to_string(), h.to_string(), comment.unwrap_or_default()));
        }
    }

    let mut out = header;
    let mut sites = 0usize;
    for scan in &scans {
        for i in 0..scan.code.len() {
            if scan.in_test[i] || scanner::find_word(&scan.code[i], "unsafe").is_empty() {
                continue;
            }
            sites += 1;
            let hash = rules::site_hash(scan, i);
            let comment = kept
                .iter()
                .find(|(f, h, _)| *h == hash && scan.rel.ends_with(f.as_str()))
                .map(|(_, _, c)| c.clone())
                .filter(|c| !c.is_empty())
                .unwrap_or_else(|| format!("# {}", scan.raw[i].trim()));
            out.push_str(&format!("{} {hash}  {comment}\n", scan.rel));
        }
    }
    std::fs::write(&inv_path, &out).map_err(|e| format!("writing {}: {e}", inv_path.display()))?;
    println!(
        "srclint: wrote {} ({sites} unsafe sites); rebuild to re-bake the include_str! copy",
        inv_path.display()
    );
    Ok(())
}

fn main() -> ExitCode {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("srclint: {e}");
            return ExitCode::from(2);
        }
    };

    if opts.update_inventory {
        return match update_inventory(&opts.root) {
            Ok(()) => ExitCode::SUCCESS,
            Err(e) => {
                eprintln!("srclint: {e}");
                ExitCode::from(2)
            }
        };
    }

    let reg = if opts.fixture_registry { Registry::fixtures() } else { Registry::builtin() };
    let analysis = match analysis::run(&opts.root, &reg) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("srclint: scan failed: {e:#}");
            return ExitCode::from(2);
        }
    };

    let suite = if opts.run_interleave { interleave::standard_suite() } else { Vec::new() };

    for f in &analysis.findings {
        eprintln!("{f}");
    }

    let root_str = opts.root.display().to_string();
    let doc = analysis::report_json(&analysis, &suite, opts.clippy_ran, &root_str, &opts.lanes);
    if let Err(e) = std::fs::write(&opts.report, format!("{doc}\n")) {
        eprintln!("srclint: writing {}: {e}", opts.report.display());
        return ExitCode::from(2);
    }

    let interleave_bad =
        suite.iter().filter(|(_, ex)| ex.violations > 0 || ex.truncated).count();
    let schedules: u64 = suite.iter().map(|(_, ex)| ex.schedules).sum();
    println!(
        "srclint: {} files, {} findings, {} unsafe sites ({} inventoried), \
         {} interleave models ({} schedules), report: {}",
        analysis.files_scanned,
        analysis.findings.len(),
        analysis.unsafe_sites,
        analysis.inventory.matched,
        suite.len(),
        schedules,
        opts.report.display()
    );

    let ok = analysis.findings.is_empty() && analysis.inventory.ok && interleave_bad == 0;
    if ok {
        ExitCode::SUCCESS
    } else {
        if !analysis.inventory.ok {
            eprintln!(
                "srclint: unsafe inventory mismatch ({} entries, {} matched, {} sites)",
                analysis.inventory.entries, analysis.inventory.matched, analysis.unsafe_sites
            );
        }
        if interleave_bad > 0 {
            eprintln!("srclint: {interleave_bad} interleave model(s) reported violations");
        }
        ExitCode::FAILURE
    }
}
