//! `srclint` — the repo's std-only static-analysis gate.
//!
//! Scans `rust/src/**/*.rs` with the rules in [`fairsquare::analysis`],
//! runs the bounded interleaving models in
//! [`fairsquare::sim::interleave`], writes `ANALYSIS_report.json`, and
//! exits nonzero on any finding, inventory mismatch, or interleaving
//! violation. `scripts/verify.sh` runs this as a hard gate.
//!
//! ```text
//! srclint [--root PATH] [--report PATH] [--clippy-ran true|false]
//!         [--fixture-registry] [--no-interleave]
//! ```
//!
//! `--root` may be a directory or a single file (the fixture tests point
//! it at one known-bad snippet at a time). `--fixture-registry` swaps in
//! the narrow fixture policy so the snippets under
//! `rust/tests/srclint_fixtures/` trip exactly their intended rule.

use std::path::PathBuf;
use std::process::ExitCode;

use fairsquare::analysis::{self, Registry};
use fairsquare::sim::interleave;

struct Opts {
    root: PathBuf,
    report: PathBuf,
    clippy_ran: Option<bool>,
    fixture_registry: bool,
    run_interleave: bool,
}

fn parse_args() -> Result<Opts, String> {
    let mut opts = Opts {
        root: PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src")),
        report: PathBuf::from("ANALYSIS_report.json"),
        clippy_ran: None,
        fixture_registry: false,
        run_interleave: true,
    };
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => {
                opts.root = PathBuf::from(args.next().ok_or("--root needs a path")?);
            }
            "--report" => {
                opts.report = PathBuf::from(args.next().ok_or("--report needs a path")?);
            }
            "--clippy-ran" => {
                let v = args.next().ok_or("--clippy-ran needs true|false")?;
                opts.clippy_ran = Some(match v.as_str() {
                    "true" | "1" => true,
                    "false" | "0" => false,
                    other => return Err(format!("--clippy-ran: expected true|false, got {other}")),
                });
            }
            "--fixture-registry" => opts.fixture_registry = true,
            "--no-interleave" => opts.run_interleave = false,
            "--help" | "-h" => {
                println!(
                    "srclint [--root PATH] [--report PATH] [--clippy-ran true|false] \
                     [--fixture-registry] [--no-interleave]"
                );
                std::process::exit(0);
            }
            other => return Err(format!("unknown argument: {other}")),
        }
    }
    Ok(opts)
}

fn main() -> ExitCode {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("srclint: {e}");
            return ExitCode::from(2);
        }
    };

    let reg = if opts.fixture_registry { Registry::fixtures() } else { Registry::builtin() };
    let analysis = match analysis::run(&opts.root, &reg) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("srclint: scan failed: {e:#}");
            return ExitCode::from(2);
        }
    };

    let suite = if opts.run_interleave { interleave::standard_suite() } else { Vec::new() };

    for f in &analysis.findings {
        eprintln!("{f}");
    }

    let root_str = opts.root.display().to_string();
    let doc = analysis::report_json(&analysis, &suite, opts.clippy_ran, &root_str);
    if let Err(e) = std::fs::write(&opts.report, format!("{doc}\n")) {
        eprintln!("srclint: writing {}: {e}", opts.report.display());
        return ExitCode::from(2);
    }

    let interleave_bad =
        suite.iter().filter(|(_, ex)| ex.violations > 0 || ex.truncated).count();
    let schedules: u64 = suite.iter().map(|(_, ex)| ex.schedules).sum();
    println!(
        "srclint: {} files, {} findings, {} unsafe sites ({} inventoried), \
         {} interleave models ({} schedules), report: {}",
        analysis.files_scanned,
        analysis.findings.len(),
        analysis.unsafe_sites,
        analysis.inventory.matched,
        suite.len(),
        schedules,
        opts.report.display()
    );

    let ok = analysis.findings.is_empty() && analysis.inventory.ok && interleave_bad == 0;
    if ok {
        ExitCode::SUCCESS
    } else {
        if !analysis.inventory.ok {
            eprintln!(
                "srclint: unsafe inventory mismatch ({} entries, {} matched, {} sites)",
                analysis.inventory.entries, analysis.inventory.matched, analysis.unsafe_sites
            );
        }
        if interleave_bad > 0 {
            eprintln!("srclint: {interleave_bad} interleave model(s) reported violations");
        }
        ExitCode::FAILURE
    }
}
