//! DSP scenario: a 64-tap low-pass FIR filter on a noisy two-tone signal,
//! run three ways and cross-checked:
//!
//!   1. the cycle-accurate Fig. 8 square engine (fixed-point, bit-true);
//!   2. the op-counted square reference (eq. 11);
//!   3. the AOT Pallas `conv1d_square` artifact through PJRT (f32).
//!
//! Reports stop-band attenuation actually achieved plus the op-count and
//! gate-area savings the square engine would buy at this tap count.
//!
//!   cargo run --release --example dsp_fir

use anyhow::Result;

use fairsquare::arith::fixed::Q;
use fairsquare::benchkit::{f, Table};
use fairsquare::coordinator::WorkloadGen;
use fairsquare::gates::report::core_comparison;
use fairsquare::linalg::conv;
use fairsquare::runtime::Engine;
use fairsquare::sim::conv::{run_fir, SquareFir};

/// windowed-sinc low-pass, cutoff 0.2·fs — the same taps model.py bakes
/// into the artifact.
fn fir_taps(n: usize) -> Vec<f64> {
    let m = (n - 1) as f64 / 2.0;
    let cutoff = 0.2;
    let mut h: Vec<f64> = (0..n)
        .map(|i| {
            let x = 2.0 * cutoff * (i as f64 - m);
            let sinc = if x == 0.0 {
                1.0
            } else {
                (std::f64::consts::PI * x).sin() / (std::f64::consts::PI * x)
            };
            let window = 0.54
                - 0.46 * (std::f64::consts::TAU * i as f64 / (n - 1) as f64).cos();
            sinc * window
        })
        .collect();
    let sum: f64 = h.iter().sum();
    h.iter_mut().for_each(|v| *v /= sum);
    h
}

fn tone_power(signal: &[f64], freq: f64) -> f64 {
    let (mut re, mut im) = (0.0, 0.0);
    for (i, &x) in signal.iter().enumerate() {
        let ang = std::f64::consts::TAU * freq * i as f64;
        re += x * ang.cos();
        im += x * ang.sin();
    }
    ((re * re + im * im).sqrt() / signal.len() as f64).max(1e-12)
}

fn main() -> Result<()> {
    const TAPS: usize = 64;
    let mut gen = WorkloadGen::new(7);
    let signal_f32 = gen.two_tone_signal(1024 + TAPS - 1);
    let signal: Vec<f64> = signal_f32.iter().map(|&x| x as f64).collect();
    let taps = fir_taps(TAPS);

    // ---- fixed-point path: Q1.14 samples, Q1.14 taps -------------------
    let q = Q::new(16, 14);
    let taps_i: Vec<i64> = taps.iter().map(|&t| q.quantise(t)).collect();
    let sig_i: Vec<i64> = signal.iter().map(|&x| q.quantise(x / 4.0)).collect();

    // Fig. 8 engine, cycle by cycle
    let mut engine8 = SquareFir::new(taps_i.clone());
    let y_engine = run_fir(|x| engine8.step(x), &sig_i);

    // eq. (11) reference + the direct baseline
    let (y_square, ops_sq) = conv::conv1d_square(&taps_i, &sig_i);
    let (y_direct, ops_di) = conv::conv1d_direct(&taps_i, &sig_i);
    assert_eq!(y_engine, y_square, "Fig.8 engine deviates from eq.(11)");
    assert_eq!(y_square, y_direct, "square trick broke the filter");

    // ---- filter quality (measured on the fixed-point output) -----------
    // undo the /4 input headroom scaling; taps are Q1.14 so the product
    // carries an extra 2^14 that to_f64 removes once — remove it again
    let y: Vec<f64> = y_engine
        .iter()
        .map(|&v| q.to_f64(v) * 4.0 / (1 << 14) as f64)
        .collect();
    let in_keep = tone_power(&signal, 0.05);
    let in_kill = tone_power(&signal, 0.40);
    let out_keep = tone_power(&y, 0.05);
    let out_kill = tone_power(&y, 0.40);
    let atten_db = 20.0 * (in_kill / in_keep * out_keep / out_kill).log10();

    let mut t = Table::new("dsp_fir — 64-tap low-pass via squares", &["metric", "value"]);
    t.row(&["pass tone (0.05 fs) kept".into(),
            f(20.0 * (out_keep / in_keep).log10(), 1) + " dB"]);
    t.row(&["stop tone (0.40 fs) cut".into(),
            f(20.0 * (out_kill / in_kill).log10(), 1) + " dB"]);
    t.row(&["relative stop-band attenuation".into(), f(atten_db, 1) + " dB"]);
    t.row(&["outputs produced".into(), y.len().to_string()]);
    t.row(&["mults (direct)".into(), ops_di.mults.to_string()]);
    t.row(&["squares (Fig.8)".into(), ops_sq.squares.to_string()]);
    t.row(&["squares per output".into(),
            f(ops_sq.squares as f64 / y.len() as f64, 2)
                + &format!(" (paper: N+1 = {})", TAPS + 1)]);

    // gate-area savings at 16-bit operands for a 64-tap engine
    let core = &core_comparison(&[16], 0)[0];
    let direct_area = TAPS as f64 * core.mult_area;
    let square_area = (TAPS + 1) as f64 * core.sq_area;
    t.row(&["multiplier area (64 taps)".into(), f(direct_area, 0) + " NAND2"]);
    t.row(&["squarer area (64+1 units)".into(), f(square_area, 0) + " NAND2"]);
    t.row(&["area saving".into(),
            f(100.0 * (1.0 - square_area / direct_area), 1) + " %"]);
    t.print();

    // ---- the AOT Pallas artifact (f32) ----------------------------------
    let dir = std::path::Path::new("artifacts");
    if !fairsquare::runtime::client::HAVE_PJRT {
        println!("\n(built without the `pjrt` feature — PJRT leg skipped)");
    } else if dir.join("manifest.json").exists() {
        let mut eng = Engine::new(dir)?;
        let got = eng.run_f32("conv1d_square", &[signal_f32.clone()])?;
        let want = eng.run_f32("conv1d_direct", &[signal_f32])?;
        let max_err = got[0]
            .iter()
            .zip(&want[0])
            .map(|(g, w)| (g - w).abs())
            .fold(0.0f32, f32::max);
        println!("\nPJRT conv1d_square vs conv1d_direct: max |err| = {max_err:.2e}");
        assert!(max_err < 1e-3);
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` for the PJRT leg)");
    }
    Ok(())
}
