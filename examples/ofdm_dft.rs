//! Communications scenario: an OFDM-style pipeline — QPSK symbols through
//! a 64-point DFT (the Fig. 13 CPM3 transform engine) and a complex
//! channel-equalisation matmul (the eq. 32/34 CPM3 matmul) — exercising
//! the complex 3-square path end to end:
//!
//!   1. bit-true fixed-point on the cycle-accurate Fig. 13 engine;
//!   2. the `dft_cpm3` / `cmatmul_3sq` AOT Pallas artifacts through PJRT;
//!   3. cross-checked against direct complex arithmetic.
//!
//!   make artifacts && cargo run --release --example ofdm_dft

use anyhow::Result;

use fairsquare::arith::fixed::Q;
use fairsquare::arith::Complex;
use fairsquare::benchkit::{f, Table};
use fairsquare::coordinator::WorkloadGen;
use fairsquare::linalg::transform::ctransform_direct;
use fairsquare::linalg::Matrix;
use fairsquare::runtime::Engine;
use fairsquare::sim::transform::Cpm3TransformEngine;

const N: usize = 64;

/// Fixed-point DFT matrix planes at Q2.13.
fn dft_matrix_q(q: Q) -> Matrix<Complex<i64>> {
    Matrix::from_fn(N, N, |k, i| {
        let ang = -std::f64::consts::TAU * (k * i) as f64 / N as f64;
        Complex::new(q.quantise(ang.cos()), q.quantise(ang.sin()))
    })
}

fn main() -> Result<()> {
    let q = Q::new(16, 13);
    let mut gen = WorkloadGen::new(0x0FD);

    // ---- Fig. 13 engine: fixed-point DFT of a QPSK symbol ---------------
    let (re, im) = gen.qpsk_symbol(N);
    let x: Vec<Complex<i64>> = re
        .iter()
        .zip(&im)
        .map(|(&r, &i)| Complex::new(q.quantise(r as f64), q.quantise(i as f64)))
        .collect();
    let w = dft_matrix_q(q);

    let mut engine = Cpm3TransformEngine::new(w.clone());
    let (got, stats) = engine.run(&x);
    let (want, _) = ctransform_direct(&w, &x);
    assert_eq!(got, want, "Fig.13 engine deviates from direct complex math");

    // numerical quality vs an f64 DFT (quantisation only — the squares are exact)
    let mut max_err = 0.0f64;
    for (k, g) in got.iter().enumerate() {
        let (mut fre, mut fim) = (0.0f64, 0.0f64);
        for (i, (&r, &ii)) in re.iter().zip(&im).enumerate() {
            let ang = -std::f64::consts::TAU * (k * i) as f64 / N as f64;
            fre += r as f64 * ang.cos() - ii as f64 * ang.sin();
            fim += r as f64 * ang.sin() + ii as f64 * ang.cos();
        }
        // engine output carries q² scaling (Q2.13 × Q2.13)
        let scale = (1i64 << 13) as f64 * (1i64 << 13) as f64;
        max_err = max_err
            .max((g.re as f64 / scale - fre).abs())
            .max((g.im as f64 / scale - fim).abs());
    }

    let ops = engine.ops();
    let mut t = Table::new("ofdm_dft — 64-point DFT on the Fig. 13 CPM3 engine", &["metric", "value"]);
    t.row(&["cycles (one symbol)".into(), stats.cycles.to_string()]);
    t.row(&["squares used".into(), ops.squares.to_string()]);
    t.row(&["squares per complex mult".into(),
            f(ops.squares as f64 / (N * N) as f64, 3) + "  (paper: -> 3)"]);
    t.row(&["general multiplications".into(), ops.mults.to_string()]);
    t.row(&["max |err| vs f64 DFT".into(), format!("{max_err:.3e} (quantisation)")]);
    t.print();

    // ---- AOT artifacts: batched DFT + channel equalisation --------------
    let dir = std::path::Path::new("artifacts");
    if !fairsquare::runtime::client::HAVE_PJRT {
        println!("\n(built without the `pjrt` feature — PJRT leg skipped)");
        return Ok(());
    }
    if !dir.join("manifest.json").exists() {
        println!("\n(artifacts/ missing — run `make artifacts` for the PJRT leg)");
        return Ok(());
    }
    let mut eng = Engine::new(dir)?;

    // batched DFT through the Pallas CPM3 transform kernel
    let bsz = 8;
    let mut xr = Vec::with_capacity(bsz * N);
    let mut xi = Vec::with_capacity(bsz * N);
    for _ in 0..bsz {
        let (r, i) = gen.qpsk_symbol(N);
        xr.extend(r);
        xi.extend(i);
    }
    let out = eng.run_f32("dft_cpm3", &[xr.clone(), xi.clone()])?;
    // reference via the direct complex matmul artifact-independent check
    let mut max_err = 0.0f32;
    for b in 0..bsz {
        for k in 0..N {
            let (mut fre, mut fim) = (0.0f64, 0.0f64);
            for i in 0..N {
                let ang = -std::f64::consts::TAU * (k * i) as f64 / N as f64;
                let (r, im_) = (xr[b * N + i] as f64, xi[b * N + i] as f64);
                fre += r * ang.cos() - im_ * ang.sin();
                fim += r * ang.sin() + im_ * ang.cos();
            }
            max_err = max_err
                .max((out[0][b * N + k] - fre as f32).abs())
                .max((out[1][b * N + k] - fim as f32).abs());
        }
    }
    println!("\nPJRT dft_cpm3 ({bsz}×{N}) vs f64 DFT: max |err| = {max_err:.2e}");
    assert!(max_err < 5e-2);

    // channel equalisation: Z = X · H with the 3-square matmul artifact
    let m = 32;
    let a: Vec<f32> = (0..m * m).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect();
    let b: Vec<f32> = (0..m * m).map(|i| ((i % 5) as f32 - 2.0) * 0.2).collect();
    let c: Vec<f32> = (0..m * m).map(|i| ((i % 7) as f32 - 3.0) * 0.15).collect();
    let s: Vec<f32> = (0..m * m).map(|i| ((i % 3) as f32 - 1.0) * 0.3).collect();
    let got = eng.run_f32("cmatmul_3sq", &[a.clone(), b.clone(), c.clone(), s.clone()])?;
    let want = eng.run_f32("cmatmul_direct", &[a, b, c, s])?;
    let max_err = got[0]
        .iter()
        .chain(&got[1])
        .zip(want[0].iter().chain(&want[1]))
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max);
    println!("PJRT cmatmul_3sq (32³ complex) vs direct: max |err| = {max_err:.2e}");
    assert!(max_err < 1e-3);
    println!("\nofdm_dft complete — complex 3-square path verified at all layers.");
    Ok(())
}
