//! TCP serving client: talk to the square-trick engine over a socket.
//!
//!   # self-contained: starts its own in-process ingress on a free port
//!   cargo run --release --example tcp_client
//!
//!   # or against an already-running front door
//!   cargo run --release -- serve --listen 127.0.0.1:7878 &
//!   cargo run --release --example tcp_client -- 127.0.0.1:7878
//!
//! Steps: (1) connect and LIST the advertised model table (name, dtype,
//! arity, admission cost); (2) send one INFER per model — dense 784→10,
//! conv NCHW 1×28×28, complex QPSK 64, and the quantized qnn lane,
//! whose int8 row travels as tagged int64 and whose exact logits are
//! argmaxed into a class right here; (3) show a typed rejection: an
//! unknown model name comes back as a `REJECTED` frame naming the valid
//! set, never a silent drop.

use anyhow::Result;

use fairsquare::coordinator::WorkloadGen;
use fairsquare::ingress::{
    self, wire, IngressServer, ModelRegistry, NativeServing, TcpClient, MODEL_NAMES,
};
use fairsquare::qnn::argmax_logits;

fn main() -> Result<()> {
    // an explicit ADDR argument targets a running server; with none, we
    // host the trio ourselves on a kernel-assigned port
    let addr_arg = std::env::args().nth(1);
    let own_server = if addr_arg.is_none() {
        let cfg = NativeServing::default();
        let mut reg = ModelRegistry::new();
        for name in MODEL_NAMES {
            ingress::register_native(&mut reg, name, &cfg)?;
        }
        Some(IngressServer::bind("127.0.0.1:0", reg)?)
    } else {
        None
    };
    let addr = match (&addr_arg, &own_server) {
        (Some(a), _) => a.clone(),
        (None, Some(s)) => s.local_addr().to_string(),
        (None, None) => unreachable!(),
    };

    // (1) one connection, many requests — the wire protocol is
    // request-serial per connection
    let mut client = TcpClient::connect(addr.as_str())?;
    let models = client.list_models()?;
    println!("connected to {addr}; {} models advertised:", models.len());
    for m in &models {
        println!(
            "  {:<8} {:<7} {:>5} -> {:<5}  cost {}",
            m.name,
            wire::dtype_name(m.dtype),
            m.row_len,
            m.out_len,
            m.row_cost
        );
    }

    // (2) one inference per model, inputs from the deterministic workload
    // generator the benches use; each row travels under its model's
    // dtype tag, so the float lanes and the quantized lane share one
    // connection
    let mut gen = WorkloadGen::new(2026);
    for m in &models {
        if wire::dtype_name(m.dtype) == "int64" {
            let row = ingress::sample_input_i64(&mut gen, &m.name)?;
            match client.infer(&m.name, &row)? {
                Ok(out) => println!(
                    "{:<8} OK   {} int8 features in, {} exact logits out -> class {}",
                    m.name,
                    row.len(),
                    out.len(),
                    argmax_logits(&out)
                ),
                Err(rej) => println!("{:<8} {rej}", m.name),
            }
        } else {
            let row = ingress::sample_input(&mut gen, &m.name)?;
            match client.infer(&m.name, &row)? {
                Ok(out) => println!(
                    "{:<8} OK   {} features in, {} out (first: {:.4})",
                    m.name,
                    row.len(),
                    out.len(),
                    out[0]
                ),
                Err(rej) => println!("{:<8} {rej}", m.name),
            }
        }
    }

    // (3) rejections are typed frames, not dropped connections: the
    // reply names the valid set and the session stays usable
    match client.infer("mystery", &[0.0f32; 4])? {
        Ok(_) => println!("mystery  unexpectedly served?!"),
        Err(rej) => println!("mystery  {rej}"),
    }

    if let Some(server) = own_server {
        let report = server.shutdown()?;
        report.check_conservation()?;
        println!(
            "\nin-process server drained: {} submitted, {} served, {} unroutable — conserved",
            report.totals.submitted, report.totals.served, report.unroutable
        );
    }
    Ok(())
}
