//! Quickstart: the square trick at every level of the stack in ~60 lines.
//!
//!   cargo run --release --example quickstart
//!
//! Steps: (1) scalar identity; (2) exact square-based matmul via the
//! op-counted reference; (3) the same matmul on the cycle-accurate systolic
//! array; (4) the AOT Pallas kernel through PJRT — all four agree.

use anyhow::Result;

use fairsquare::arith;
use fairsquare::linalg::{matmul, Matrix};
use fairsquare::runtime::Engine;
use fairsquare::sim::systolic::{systolic_matmul, PeKind};
use fairsquare::testkit::Rng;

fn main() -> Result<()> {
    // (1) the basic mechanism (eq. 1): ab = ½((a+b)² − a² − b²)
    let (a, b) = (1234, -567);
    assert_eq!(arith::pm_product(a, b), a * b);
    println!("eq.(1) scalar identity          OK   ({a}·{b} = {})", a * b);

    // (2) square-based matmul (eq. 4/5), exact over integers
    let mut rng = Rng::new(2026);
    let am = Matrix::random(&mut rng, 8, 12, -100, 100);
    let bm = Matrix::random(&mut rng, 12, 6, -100, 100);
    let (direct, ops_d) = matmul::matmul_direct(&am, &bm);
    let (square, ops_s) = matmul::matmul_square(&am, &bm);
    assert_eq!(direct, square);
    println!(
        "eq.(4) square matmul            OK   ({} mults -> {} squares, ratio {:.3})",
        ops_d.mults,
        ops_s.squares,
        ops_s.square_ratio_vs(&ops_d)
    );

    // (3) the Fig. 2/3 systolic array computes the same thing in silicon time
    let run = systolic_matmul(PeKind::Square, &am, &bm);
    assert_eq!(run.c, direct);
    println!(
        "Fig.2/3 systolic array          OK   ({} cycles, {:.1}% PE utilization)",
        run.stats.cycles,
        100.0 * run.stats.utilization()
    );

    // (4) the AOT-compiled Pallas kernel through the PJRT runtime
    let dir = std::path::Path::new("artifacts");
    if !fairsquare::runtime::client::HAVE_PJRT {
        println!("L1 Pallas kernel via PJRT       SKIP (built without the `pjrt` feature)");
    } else if dir.join("manifest.json").exists() {
        let mut engine = Engine::new(dir)?;
        let af: Vec<f32> = (0..64 * 64).map(|i| ((i % 13) as f32 - 6.0) * 0.25).collect();
        let bf: Vec<f32> = (0..64 * 64).map(|i| ((i % 7) as f32 - 3.0) * 0.5).collect();
        let got = engine.run_f32("matmul_square_m", &[af.clone(), bf.clone()])?;
        let want = engine.run_f32("matmul_direct_m", &[af, bf])?;
        let max_err = got[0]
            .iter()
            .zip(&want[0])
            .map(|(g, w)| (g - w).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-2, "kernel disagrees: {max_err}");
        println!("L1 Pallas kernel via PJRT       OK   (64x64x64, max |err| = {max_err:.2e})");
    } else {
        println!("L1 Pallas kernel via PJRT       SKIP (run `make artifacts` first)");
    }

    println!("\nquickstart complete — see `fairsquare --help` style usage in README.md");
    Ok(())
}
