//! Hardware designer's view: full gate-level report plus a worked
//! silicon-budget example for a 16×16 square-based tensor core tile
//! (the §3.3 architecture) at several operand widths.
//!
//!   cargo run --release --example hardware_report

use fairsquare::arith::fixed::BitBudget;
use fairsquare::benchkit::{f, Table};
use fairsquare::gates::blocks::{mac_block, pmac_block, DFF_AREA};
use fairsquare::gates::report::{ablation, block_comparison, core_comparison};

fn main() {
    let widths = [4usize, 8, 12, 16, 20, 24];

    // E4 — cores, with the Monte-Carlo switching (power) proxy on
    let mut t = Table::new(
        "E4 — multiplier vs squarer cores (switching = toggles/gate/cycle)",
        &["n", "mult area", "sq area", "ratio", "mult delay", "sq delay",
          "mult sw", "sq sw"],
    );
    for r in core_comparison(&widths, 300) {
        t.row(&[
            r.n.to_string(),
            f(r.mult_area, 1),
            f(r.sq_area, 1),
            f(r.area_ratio, 3),
            f(r.mult_delay, 1),
            f(r.sq_delay, 1),
            f(r.mult_switching, 3),
            f(r.sq_switching, 3),
        ]);
    }
    t.print();

    // ablation: architecture variants
    let mut t = Table::new("reduction-tree ablation", &["variant", "n", "gates", "area", "delay"]);
    for r in ablation(&[8, 16, 24]) {
        t.row(&[r.name.into(), r.n.to_string(), r.gates.to_string(),
                f(r.area, 1), f(r.delay, 1)]);
    }
    t.print();

    // F1/F9/F12 blocks
    let mut t = Table::new(
        "datapath blocks (Fig. 1 / 9 / 12), N = 256-term accumulation",
        &["block", "n", "total area", "rel", "delay"],
    );
    for r in block_comparison(&[8, 16], 256) {
        t.row(&[r.name.into(), r.n.to_string(), f(r.total_area, 1),
                f(r.rel_area, 3), f(r.critical_path, 1)]);
    }
    t.print();

    // worked example: a 16×16 tensor-core tile (§3.3)
    let (m, p, n_terms) = (16usize, 16usize, 4096u64);
    let mut t = Table::new(
        "16×16 square tensor core tile, K accumulation = 4096 (worked example)",
        &["operand bits", "MAC-core area", "PMAC-core area", "saving",
          "acc bits (MAC)", "acc bits (PMAC)", "SRAM for Sa/Sb (bits)"],
    );
    for bits in [8u32, 12, 16] {
        let mac = mac_block(bits as usize, n_terms);
        let pmac = pmac_block(bits as usize, n_terms);
        let bb = BitBudget::new(bits, n_terms);
        let grid = (m * p) as f64;
        let mac_area = grid * mac.total_area();
        let pmac_area = grid * pmac.total_area();
        // corrections live in a small side SRAM: (M+P) accumulator words
        let corr_bits = (m + p) as u64 * bb.accumulator_bits() as u64;
        t.row(&[
            bits.to_string(),
            f(mac_area, 0),
            f(pmac_area, 0),
            f(100.0 * (1.0 - pmac_area / mac_area), 1) + " %",
            bb.mac_accumulator_bits().to_string(),
            bb.accumulator_bits().to_string(),
            format!("{corr_bits} (~{:.0} NAND2)", corr_bits as f64 * DFF_AREA),
        ]);
    }
    t.print();

    println!("\nhonest accounting: the PMAC accumulator is {}+ bits wider and the",
             BitBudget::new(16, 4096).register_overhead_bits());
    println!("corrections need a side SRAM — both included above; the net tile");
    println!("saving still tracks the ~2x squarer advantage (paper §1/§12).");
}
