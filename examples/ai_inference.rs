//! End-to-end driver (experiment E6): serve MLP inference with every dense
//! layer computed by the square-based Pallas kernel, loaded from the AOT
//! artifacts and driven through the full coordinator stack — request queue,
//! dynamic batcher, PJRT worker, shadow verification against the
//! direct-matmul twin.
//!
//!   make artifacts && cargo run --release --example ai_inference
//!
//! Prints the serving report recorded in EXPERIMENTS.md §E6.

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use fairsquare::benchkit::{f, Table};
use fairsquare::coordinator::{InferenceServer, PjrtExecutor, WorkloadGen};

const REQUESTS: usize = 512;
const RPS: f64 = 4_000.0;

fn run_one(model: &'static str, shadow: Option<&'static str>) -> Result<(f64, f64, f64, u64, u64)> {
    let dir = std::path::PathBuf::from("artifacts");
    let dir2 = dir.clone();
    let shadow_every = if shadow.is_some() { 4 } else { 0 };
    // workers = 1: the PJRT engine is not `Send`, so the artifact path
    // cannot shard (the native engine can — see `serve --native --workers`)
    let srv = InferenceServer::start(
        32,
        Duration::from_millis(2),
        2048,
        shadow_every,
        1,
        move |_| PjrtExecutor::new(&dir, model),
        move |_| shadow.map(|s| PjrtExecutor::new(&dir2, s)).transpose(),
    )?;

    // warm the executables so the measurement sees steady state
    let mut gen = WorkloadGen::new(0xA1);
    for _ in 0..2 {
        let _ = srv.infer(gen.mnist_like())?;
    }

    let gaps = gen.arrival_gaps_us(REQUESTS, RPS);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(REQUESTS);
    for gap in gaps {
        std::thread::sleep(Duration::from_micros(gap.min(2_000)));
        pending.push(srv.submit(gen.mnist_like())?);
    }
    for rx in pending {
        rx.recv()
            .context("worker died")?
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = srv.shutdown()?;
    Ok((
        REQUESTS as f64 / wall,
        stats.latency.p50_us,
        stats.latency.p99_us,
        stats.shadow_checks,
        stats.shadow_failures,
    ))
}

fn main() -> Result<()> {
    if !fairsquare::runtime::client::HAVE_PJRT {
        bail!("built without the `pjrt` feature — rebuild with a vendored xla crate, \
               or use `fairsquare serve --native` for the in-process engine");
    }
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        bail!("artifacts/ missing — run `make artifacts` first");
    }

    println!("serving 512 MNIST-like requests through each MLP twin…");
    let (thr_d, p50_d, p99_d, _, _) = run_one("mlp_direct", None)?;
    let (thr_s, p50_s, p99_s, checks, fails) =
        run_one("mlp_square", Some("mlp_direct"))?;

    let mut t = Table::new(
        "E6 — MLP serving: direct vs square-based artifacts",
        &["metric", "mlp_direct", "mlp_square"],
    );
    t.row(&["throughput (rows/s)".into(), f(thr_d, 0), f(thr_s, 0)]);
    t.row(&["p50 latency (µs)".into(), f(p50_d, 0), f(p50_s, 0)]);
    t.row(&["p99 latency (µs)".into(), f(p99_d, 0), f(p99_s, 0)]);
    t.row(&["shadow checks".into(), "-".into(), checks.to_string()]);
    t.row(&["shadow failures".into(), "-".into(), fails.to_string()]);
    t.print();

    if fails > 0 {
        bail!("square model disagreed with the direct twin");
    }
    println!("\nsquare-based artifact serves identical predictions (shadow-verified).");
    println!("CPU throughput is lower for the square graph — the win is silicon");
    println!("area (see `fairsquare gates`), not software FLOPs; EXPERIMENTS.md §E6.");
    Ok(())
}
